"""Train a small LM for a few hundred steps with fault-tolerant
checkpointing (auto-resume if re-run after an interruption).

    PYTHONPATH=src python examples/train_tiny.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_tiny")
    ap.add_argument("--width", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")
    # widen the smoke config a bit so there is something to learn
    cfg = dataclasses.replace(cfg, d_model=args.width, d_ff=args.width * 4,
                              vocab_size=512, num_layers=4)
    trainer = Trainer(
        cfg,
        TrainConfig(steps=args.steps, ckpt_every=25, ckpt_dir=args.ckpt_dir,
                    log_every=20),
        DataConfig(batch=8, seq_len=64, branching=4, seed=21),
        opt=AdamWConfig(lr=3e-3, warmup_steps=20))
    if trainer.start_step:
        print(f"resuming from step {trainer.start_step}")
    losses = trainer.run()
    uniform = trainer.data.uniform_nll()
    head = sum(losses[:5]) / len(losses[:5])
    tail = sum(losses[-5:]) / len(losses[-5:])
    print(f"\nloss: {head:.3f} -> {tail:.3f} (uniform baseline {uniform:.3f})")
    assert tail < head - 0.2, "no learning happened"


if __name__ == "__main__":
    main()
