"""End-to-end driver (the paper's scenario): cloud-native serving with
profiling, HPA autoscaling, load balancing and migration — on real JAX
engines (reduced model, CPU).

A burst of requests hits one replica; queue pressure trips the HPA law;
the orchestrator spins up replicas (requests route via least-loaded
balancing and can migrate between engines); the fleet scales back down
after the burst drains.

    PYTHONPATH=src python examples/serve_autoscaling.py
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.autoscaler import HPAConfig
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch + "-smoke")

    def make_engine():
        return InferenceEngine(cfg, capacity=2, max_len=48, buckets=(8, 16),
                               seed=11,
                               sched=SchedulerConfig(max_prefill_per_step=1))

    orch = Orchestrator(make_engine, OrchestratorConfig(
        min_replicas=1,
        hpa=HPAConfig(metric="queue", target=2.0, max_replicas=4,
                      tolerance=0.0, stabilization_s=2.0,
                      scale_down_cooldown_s=30.0),
        control_every_steps=2))

    rng = np.random.default_rng(0)
    print(f"burst: {args.requests} requests -> 1 replica (capacity 2)")
    for i in range(args.requests):
        orch.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 12)))],
            sampling=SamplingParams(max_new_tokens=4)))

    done = orch.run(max_steps=600)
    print(f"completed {len(done)}/{args.requests}")
    print(f"scale events (t, replicas): "
          f"{[(round(t, 1), n) for t, n in orch.scale_history]}")
    print(f"final replicas: {len(orch.engines)}")
    print(f"migrations: {len(orch.migrations.events)}")
    ttfts = [r.ttft for r in done if r.ttft is not None]
    print(f"mean ttft {np.mean(ttfts)*1e3:.0f}ms  "
          f"p95 {np.percentile(ttfts, 95)*1e3:.0f}ms")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
