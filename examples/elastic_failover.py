"""Fault tolerance end-to-end: replica failure during serving + live
request migration, and trainer crash/auto-resume.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import shutil

import numpy as np

from repro.configs import get_config
from repro.core.migration import MigrationManager
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.training.data import DataConfig
from repro.training.train_loop import Trainer, TrainConfig


def serving_failover():
    print("== serving failover: engine B dies mid-generation ==")
    cfg = get_config("qwen2-0.5b-smoke")
    eng_a = InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16), seed=3)
    eng_b = InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16), seed=3)
    eng_b.params = eng_a.params            # same model replica weights

    rng = np.random.default_rng(1)
    reqs = []
    for i in range(4):
        r = Request(rid=i,
                    prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 8)],
                    sampling=SamplingParams(max_new_tokens=8))
        reqs.append(r)
        (eng_a if i < 2 else eng_b).submit(r)

    for _ in range(5):                     # both engines make progress
        eng_a.step()
        eng_b.step()

    print(f"  engine B 'fails' with {eng_b.pool.used} live requests; "
          "draining to A via live migration")
    mgr = MigrationManager()
    for rid in [q.rid for q in list(eng_b.row_req.values())]:
        ev = mgr.migrate(eng_b, eng_a, rid, now=0.0, src_idx=1, dst_idx=0)
        print(f"  migrated rid={rid}: {ev.bytes/1e3:.1f} kB KV, "
              f"handoff {ev.duration_s*1e3:.1f} ms (cost model)")
    done = eng_a.run(max_steps=200)
    assert len(done) == 4 and all(len(r.output) == 8 for r in done)
    print(f"  all {len(done)} requests completed on A "
          f"({sum(r.migrations for r in done)} migrated)\n")


def training_failover():
    print("== training failover: crash at step 9, auto-resume ==")
    cfg = get_config("qwen2-0.5b-smoke")
    d = "/tmp/repro_failover_ckpt"
    shutil.rmtree(d, ignore_errors=True)
    tc = TrainConfig(steps=15, ckpt_every=4, ckpt_dir=d, log_every=100,
                     async_ckpt=False)
    dc = DataConfig(batch=2, seq_len=16)
    try:
        Trainer(cfg, tc, dc, fail_at_step=9).run()
    except RuntimeError as e:
        print(f"  {e}")
    t2 = Trainer(cfg, tc, dc)
    print(f"  restarted: resumed from committed step {t2.start_step}")
    losses = t2.run()
    print(f"  completed to step 15, final loss {losses[-1]:.3f}")


if __name__ == "__main__":
    serving_failover()
    training_failover()
