"""Quickstart: the public API in ~60 lines.

1. pick an architecture config (--arch, reduced for CPU)
2. train it a few steps on the synthetic stream
3. serve a few requests through the continuous-batching engine

    PYTHONPATH=src python examples/quickstart.py --arch qwen2-0.5b
"""
import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.training.data import DataConfig
from repro.training.train_loop import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")
    print(f"== {args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) ==")

    # ---- train ------------------------------------------------------------
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(cfg, TrainConfig(steps=args.steps, ckpt_every=50,
                                           ckpt_dir=d, log_every=4),
                          DataConfig(batch=4, seq_len=32))
        losses = trainer.run()
    print(f"train: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {args.steps} steps")

    # ---- serve ------------------------------------------------------------
    eng = InferenceEngine(cfg, params=trainer.params, capacity=4, max_len=64,
                          buckets=(8, 16))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 12)))],
            sampling=SamplingParams(max_new_tokens=6, temperature=0.8,
                                    top_k=40)))
    done = eng.run(max_steps=200)
    for r in done:
        print(f"req {r.rid}: ttft={r.ttft*1e3:.0f}ms out={r.output}")
    print(f"served {len(done)}/5 requests, "
          f"{sum(len(r.output) for r in done)} tokens")


if __name__ == "__main__":
    main()
