import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Perf-iteration driver (§Perf): lower one cell with PerfConfig overrides and
# report the roofline terms + top contributors to the dominant term.
#
#   PYTHONPATH=src python -m benchmarks.hillclimb --arch gemma3-27b \
#       --shape train_4k --perf partitioning=zero3 attn_impl=triangle
#
# Each run appends a JSON record to benchmarks/out/hillclimb.jsonl so the
# hypothesis -> change -> before/after log in EXPERIMENTS.md is replayable.

import argparse
import json
import time

from repro.configs import SHAPES, get_config
from repro.configs.perf import with_overrides
from repro.launch import hlo as H
from repro.launch.build import build_cell, default_perf
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.dryrun import parse_perf_overrides


def run(arch: str, shape_name: str, overrides: dict, *, debug_top: bool = True,
        out: str | None = "benchmarks/out/hillclimb.jsonl") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    perf = with_overrides(default_perf(cfg, shape), **overrides)
    mesh = make_production_mesh()
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, perf)
    with mesh:
        compiled = cell.jitted.lower(*cell.abstract_args).compile()
    txt = compiled.as_text()
    mod = H.HloModule(txt)
    flops = mod.flops()
    byts = mod.bytes_accessed()
    coll = mod.collectives()
    mem = H.memory_per_device(compiled)
    terms = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": byts / HBM_BW,
        "collective_s": coll.get("total", 0.0) / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "overrides": overrides,
        **{k: round(v, 4) for k, v in terms.items()},
        "dominant": dominant,
        "peak_gib": round(mem["peak_bytes"] / 2**30, 2),
        "compile_s": round(time.time() - t0, 1),
    }
    print(json.dumps(rec, indent=None))
    if debug_top:
        what = "collectives" if dominant == "collective_s" else "bytes"
        print(f"\ntop {what} contributors:")
        for (comp, op, name), b in H.top_ops(mod, what):
            print(f"  {b:.3e}  {op:24s} {name:48s} {comp}")
    if out:
        with open(out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--perf", nargs="*", default=[])
    ap.add_argument("--no-debug", action="store_true")
    a = ap.parse_args()
    run(a.arch, a.shape, parse_perf_overrides(a.perf), debug_top=not a.no_debug)
