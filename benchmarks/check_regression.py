"""Bench-regression CI gate.

Compares a fresh bench JSON (``engine_bench.py --json``) against the
committed baseline under ``benchmarks/baselines/`` and fails (exit != 0)
when any gated metric regresses beyond its tolerance.  The gated metrics
are deliberately the *deterministic* ones — token counts, hit rates, block
peaks, drain steps — which are bit-reproducible for a pinned ``--seed``;
wall-clock numbers (tokens/s, TTFT seconds) are excluded because shared CI
runners make them meaningless to gate on.

Tolerances are per metric: ``rel`` is the allowed relative regression
(0.10 = a >=10% regression fails), ``abs_slack`` is an additional absolute
allowance for small integer counts where one unit is a large fraction
(e.g. drain_steps with a baseline of 1).  Improvements never fail.

Re-baselining (intentional changes only): re-run the bench with the CI
seed and overwrite the baseline file, e.g.

    PYTHONPATH=src python benchmarks/engine_bench.py --mode directory \
        --seed 0 --json benchmarks/baselines/BENCH_directory.json

and say why in the commit message.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINE_DIR = pathlib.Path(__file__).resolve().parent / "baselines"

#: metric path -> (higher_is_better, rel tolerance, absolute slack).
#: Paths index nested dicts with '.'.
GATES: dict[str, dict[str, tuple[bool, float, float]]] = {
    "paged": {
        "paged.prefix_hit_rate": (True, 0.10, 0.0),
        "prefill_saved_frac": (True, 0.10, 0.0),
        "paged.prefill_tokens_true": (False, 0.10, 0.0),
        "paged.kv_blocks_peak": (False, 0.15, 1.0),
        "paged.finished": (True, 0.0, 0.0),
    },
    "migrate": {
        "drain_speedup_steps": (True, 0.25, 0.0),
        "migration.drain_steps": (False, 0.10, 1.0),
        "migration.migrated": (True, 0.0, 1.0),
        "migration.bytes_transferred": (False, 0.25, 0.0),
    },
    "directory": {
        "directory.cluster_hit_rate": (True, 0.10, 0.0),
        "directory.prefill_tokens_true": (False, 0.10, 0.0),
        "hit_rate_gain_vs_prefix": (True, 0.50, 0.0),
        "prefill_saved_vs_prefix": (True, 0.50, 0.0),
        "directory.mean_ttft_steps": (False, 0.25, 0.5),
    },
    # transport runs entirely on the logical step clock with seeded fault
    # schedules, so drain steps, chunk counts, and hit rates are all
    # bit-reproducible for the pinned seed
    "transport": {
        "overlap_speedup_steps": (True, 0.25, 0.0),
        "overlap.drain_steps": (False, 0.10, 1.0),
        "overlap.migrated": (True, 0.0, 1.0),
        "overlap.chunks": (True, 0.25, 1.0),
        "directory.hit_ratio": (True, 0.10, 0.0),
        "directory.lossless.cluster_hit_rate": (True, 0.10, 0.0),
    },
    # the stream sweep runs on the logical step clock, so TTFT percentiles
    # and goodput are seed-deterministic and gateable (unlike the wall-clock
    # TTFT seconds of the other modes)
    "stream": {
        "stream_equal_frac": (True, 0.0, 0.0),       # exact: 1.0 or broken
        "qps_3p0.served": (True, 0.0, 0.0),
        "qps_3p0.slo_goodput": (True, 0.05, 0.0),
        "qps_3p0.ttft_p90_steps": (False, 0.15, 1.0),
        "qps_1p5.ttft_p90_steps": (False, 0.15, 1.0),
        "goodput_gain_vs_fcfs": (True, 0.0, 0.05),
    },
    # the proactive scenario suite replays identical seeded traces under
    # both controllers on the logical step clock: goodputs, gains, TTFT
    # steps, and scale-up lead are seed-deterministic.  The positive floor
    # on flash_goodput_gain (baseline * 0.1 after rel tolerance) is the
    # acceptance criterion that proactive beats reactive on the flash
    # crowd — a fresh run where the gain drops to <= 0 always fails.
    "proactive": {
        "scenarios.flash.proactive.served": (True, 0.0, 0.0),  # exact: all
        "scenarios.flash.proactive.slo_goodput": (True, 0.05, 0.0),
        "flash_goodput_gain": (True, 0.90, 0.0),
        "flash_scaleup_lead_steps": (True, 0.50, 0.0),
        "scenarios.flash.proactive.p95_ttft_steps": (False, 0.25, 1.0),
        "mean_goodput_gain": (True, 0.50, 0.02),
        "ramp.lead_s": (True, 0.25, 0.0),
    },
    # multi-model registry runs on the logical step clock: served counts,
    # cold-start step counts, replica states, and the weighted-fair tenant
    # index are all seed-deterministic
    "multimodel": {
        "base.served": (True, 0.0, 0.0),             # exact: all admitted
        "base.slo_goodput": (True, 0.05, 0.0),
        "draft.served": (True, 0.0, 0.0),
        "draft.cold_starts": (True, 0.0, 0.0),       # exact: 2 wakeups
        "draft.cold_start_steps": (False, 0.0, 0.0),  # exact: spec'd warmup
        "draft.replicas_final": (False, 0.0, 0.0),   # exact: back to zero
        "tenant_fairness_jain": (True, 0.05, 0.0),
    },
}


def _dig(d: dict, path: str):
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def compare(bench: str, fresh: dict, baseline: dict) -> list[str]:
    """Failure messages for every gated metric of ``bench`` that regressed
    (empty list = gate passes).  A metric missing from either file is a
    failure: silently dropping a gated metric is itself a regression."""
    failures = []
    for path, (higher, rel, slack) in GATES[bench].items():
        base = _dig(baseline, path)
        new = _dig(fresh, path)
        if base is None or new is None:
            failures.append(f"{path}: missing (baseline={base}, fresh={new})")
            continue
        base, new = float(base), float(new)
        if higher:
            floor = base * (1.0 - rel) - slack
            if new < floor:
                failures.append(
                    f"{path}: {new:.6g} < allowed {floor:.6g} "
                    f"(baseline {base:.6g}, rel {rel:.0%}, slack {slack:g})")
        else:
            ceil = base * (1.0 + rel) + slack
            if new > ceil:
                failures.append(
                    f"{path}: {new:.6g} > allowed {ceil:.6g} "
                    f"(baseline {base:.6g}, rel {rel:.0%}, slack {slack:g})")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", choices=sorted(GATES), required=True)
    ap.add_argument("--fresh", required=True, metavar="PATH",
                    help="metrics JSON from the fresh CI bench run")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="baseline JSON (default: "
                         "benchmarks/baselines/BENCH_<bench>.json)")
    args = ap.parse_args(argv)
    baseline_path = pathlib.Path(args.baseline) if args.baseline else \
        BASELINE_DIR / f"BENCH_{args.bench}.json"
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = compare(args.bench, fresh, baseline)
    n = len(GATES[args.bench])
    if failures:
        print(f"REGRESSION GATE FAILED [{args.bench}] — "
              f"{len(failures)}/{n} metrics out of tolerance "
              f"(baseline {baseline_path}):")
        for msg in failures:
            print(f"  {msg}")
        print("If the change is an intentional trade-off, re-baseline "
              "(see module docstring) and justify it in the commit.")
        return 1
    print(f"regression gate passed [{args.bench}]: {n} metrics within "
          f"tolerance of {baseline_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
