"""Load-prediction ablation (paper §3 'Accurate load prediction').

Promoted into the CI-gated scenario suite: ``engine_bench.py --mode
proactive`` runs the full goodput-driven reactive-vs-proactive comparison
(diurnal / flash crowd / tenant hotspot / churn replay on the real
cluster stack) and embeds this module's deterministic ramp ablation as
its ``ramp`` result.  Kept runnable standalone for quick iteration on
the controllers themselves.

Two results:

1. **Ramp trigger time (deterministic unit ablation)** — a linearly rising
   load metric crosses the HPA target at t_cross; the reactive controller
   fires then, the proactive controller (Holt-Winters forecast at the
   cold-start horizon) fires ~horizon earlier — replicas are warm when the
   load arrives instead of ``cold_start_s`` late.

2. **Metric-choice lag (cluster burst)** — with the paper's latency metric,
   scaling lags a rate burst because completed-job latency only reflects
   the burst after jobs *finish* (~a full E2E later) plus the 15 s metric
   window; queue depth responds within one control period.  This
   quantifies why the platform profiles queue/arrival signals, not just
   latencies.
"""
from __future__ import annotations

import random

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.core.cluster import (ClusterConfig, SimCluster, SimJob,
                                llama2_13b_a100_costs)
from repro.core.predictor import HoltWinters


# ------------------------------------------------------------ 1. unit ramp
def ramp_trigger_times(horizon_s: float = 60.0, target: float = 10.0,
                       slope: float = 0.05, dt: float = 5.0) -> dict:
    """Metric m(t) = slope * t; returns first scale-up time per mode."""
    out = {}
    for proactive in (False, True):
        cfg = HPAConfig(metric="queue", target=target, tolerance=0.0,
                        max_replicas=8, proactive=proactive,
                        horizon_s=horizon_s)
        a = Autoscaler(cfg, HoltWinters(dt=dt) if proactive else None)
        t, n, fired = 0.0, 1, None
        while t < 600.0 and fired is None:
            m = slope * t
            new = a.evaluate(t, n, m)
            if new > n:
                fired = t
            n = new
            t += dt
        out["proactive" if proactive else "reactive"] = fired
    out["lead_s"] = (out["reactive"] or 0) - (out["proactive"] or 0)
    return out


# ------------------------------------------------- 2. cluster metric lag
def burst_scaleup_lag(metric: str, duration_s: float = 900.0,
                      seed: int = 4) -> float | None:
    """First scale-up time relative to a rate burst starting at t=300."""
    costs = llama2_13b_a100_costs()
    target = {"latency": 15.0, "queue": 1.2}[metric]
    hpa = HPAConfig(metric=metric, target=target, min_replicas=1,
                    max_replicas=3, stabilization_s=30.0,
                    scale_down_cooldown_s=1e9)
    cl = SimCluster(ClusterConfig(seed=1), costs, hpa=hpa, hpa_targets=[27])
    rng = random.Random(seed)
    t, jid = 0.0, 0
    while t < duration_s:
        rate = 0.09 if t >= 300.0 else 0.008
        t += rng.expovariate(rate)
        if t >= duration_s:
            break
        cl.submit(SimJob(jid, 16, rng.randint(50, 2048), t_submit=t))
        jid += 1
    cl.run(until=duration_s)
    scaler = cl.services[27].autoscaler
    ups = [t_ for t_, c, nw, _ in scaler.decisions if nw > c and t_ >= 300.0]
    return (ups[0] - 300.0) if ups else None


def run(verbose: bool = True) -> dict:
    ramp = ramp_trigger_times()
    lag_lat = burst_scaleup_lag("latency")
    lag_q = burst_scaleup_lag("queue")
    res = {"ramp": ramp, "lag_latency_s": lag_lat, "lag_queue_s": lag_q}
    if verbose:
        print(f"ramp trigger: reactive t={ramp['reactive']}s, proactive "
              f"t={ramp['proactive']}s -> {ramp['lead_s']:.0f}s lead "
              f"(cold start hidden when lead >= cold_start_s=12)")
        print(f"burst scale-up lag: latency-metric {lag_lat}s vs "
              f"queue-metric {lag_q}s after burst onset")
    return res


if __name__ == "__main__":
    run()
