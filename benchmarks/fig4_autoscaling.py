"""Paper Fig. 4 — latency & throughput, w/o vs with CN autoscaling.

Sweeps batch size with the calibrated simulator; at batch 62 the paper
reports bottleneck-layer/E2E latency 15.23 s -> 12.28 s and system
throughput 4.07 -> 5.05 QPS when the Kubernetes HPA targets the bottleneck
layer's microservice.  HPA: custom latency threshold, 15 s metric window,
max 3 replicas (one per cluster node).
"""
from __future__ import annotations

from repro.core.autoscaler import HPAConfig
from repro.core.cluster import (ClusterConfig, SimCluster, closed_loop,
                                llama2_13b_a100_costs)

BATCHES = (2, 8, 16, 32, 48, 62)
WARMUP_S = 120.0


def run_one(batch: int, autoscale: bool, duration_s: float = 900.0,
            seed: int = 2) -> dict:
    costs = llama2_13b_a100_costs()
    hpa = HPAConfig(metric="latency", target=2.0, min_replicas=1,
                    max_replicas=3, stabilization_s=30.0) if autoscale else None
    cl = SimCluster(ClusterConfig(seed=1), costs, hpa=hpa, hpa_targets=[27])
    closed_loop(cl, users=1, batch=batch, duration_s=duration_s, seed=seed)
    e2e = cl.mean_e2e(t0=WARMUP_S)
    return {
        "batch": batch,
        "autoscale": autoscale,
        "e2e_s": e2e,
        "qps": batch / e2e if e2e else 0.0,
        "layer27_s": cl.stage_latency_stats("layer/27", t0=WARMUP_S)["mean"],
        "replicas27": len(cl.services[27].replicas),
    }


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for b in BATCHES:
        for scale in (False, True):
            rows.append(run_one(b, scale))
    if verbose:
        print("batch,autoscale,e2e_s,qps,layer27_s,replicas27")
        for r in rows:
            print(f"{r['batch']},{int(r['autoscale'])},{r['e2e_s']:.2f},"
                  f"{r['qps']:.2f},{r['layer27_s']:.2f},{r['replicas27']}")
        wo = next(r for r in rows if r["batch"] == 62 and not r["autoscale"])
        w = next(r for r in rows if r["batch"] == 62 and r["autoscale"])
        print(f"\nbatch 62: latency {wo['e2e_s']:.2f}s -> {w['e2e_s']:.2f}s "
              f"(paper 15.23 -> 12.28), QPS {wo['qps']:.2f} -> {w['qps']:.2f} "
              f"(paper 4.07 -> 5.05)")
    return rows


if __name__ == "__main__":
    run()
