"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads benchmarks/out/dryrun.jsonl (written by repro.launch.dryrun) and
derives, per (arch x shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s     [s]
    memory term     = HLO_bytes_per_device / HBM_bw          [s]
    collective term = wire_bytes_per_device / link_bw        [s]

plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).

HLO numbers come from the static walker in repro.launch.hlo (while bodies
multiplied by trip count; see that module for the byte-accounting rules).
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import params as P
from repro.models.lm import make_model

DRYRUN = os.path.join(os.path.dirname(__file__), "out", "dryrun.jsonl")


def active_params(cfg) -> tuple[int, int]:
    """(N_total, N_active_per_token)."""
    model = make_model(cfg)
    total = P.count_params(model.param_specs())
    active = total
    if cfg.num_experts:
        n_moe_layers = sum(cfg.layer_is_moe(i) for i in range(cfg.num_layers))
        per_layer_expert = 3 * cfg.num_experts * cfg.d_model * cfg.moe_d_ff
        active -= n_moe_layers * per_layer_expert
        active += n_moe_layers * 3 * cfg.experts_per_token * cfg.d_model * cfg.moe_d_ff
    return total, active


def model_flops(cfg, shape) -> float:
    _, act = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * act * shape.global_batch * shape.seq_len
    return 2.0 * act * shape.global_batch      # decode: one token per row


def min_decode_bytes(cfg, shape) -> float:
    """Lower bound on global HBM traffic for one decode step: read active
    params once + read the live KV/SSM state for every row.  The
    bandwidth-efficiency metric for decode cells is min_bytes / HLO_bytes."""
    from repro.launch.specs import decode_specs
    import numpy as np
    _, act = active_params(cfg)
    param_bytes = 2.0 * act                    # bf16
    d = decode_specs(cfg, shape)
    cache_bytes = sum(float(np.prod(s.shape)) * s.dtype.itemsize
                      for s in __import__("jax").tree.leaves(d["caches"]))
    return param_bytes + cache_bytes


def load_records(path: str = DRYRUN, mesh: str = "16x16") -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("mesh") == mesh:
            recs[(r["arch"], r["shape"])] = r   # latest wins
    return list(recs.values())


def analyze(rec: dict, chips: int = 256) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    compute_s = rec["flops_per_device"] / PEAK_FLOPS_BF16
    memory_s = rec["bytes_per_device"] / HBM_BW
    coll_s = rec["collectives"].get("total", 0.0) / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = rec["flops_per_device"] * chips
    ratio = mf / hlo_total if hlo_total else 0.0
    bound_s = max(terms.values())
    # decode cells are intrinsically bandwidth-bound: score them by traffic
    # efficiency (ideal bytes / compiled bytes) instead of an MFU-like ratio
    mem_eff = None
    if shape.kind == "decode":
        mem_eff = (min_decode_bytes(cfg, shape) / chips) / \
            max(rec["bytes_per_device"], 1.0)
    suggestions = {
        "compute": "cut redundant compute: triangle attention chunks, lower "
                   "remat recompute, or drop TP replication of attention",
        "memory": "fuse attention (Pallas flash kernel keeps scores in VMEM) "
                  "and shrink remat boundaries / KV dtype",
        "collective": "reshard: fewer TP all-reduces (dp/zero3 rules), "
                      "overlap collectives with compute via async decomposition",
    }
    frac = (mf / PEAK_FLOPS_BF16 / chips) / bound_s if bound_s else 0.0
    if mem_eff is not None:
        frac = mem_eff                 # decode: bandwidth-efficiency score
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "kind": shape.kind,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "suggestion": suggestions[dominant],
    }


def table(mesh: str = "16x16", verbose: bool = True) -> list[dict]:
    rows = [a for r in load_records(mesh=mesh) if (a := analyze(r))]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if verbose:
        print("arch,shape,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_fraction,peak_GiB")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['compute_s']:.4g},"
                  f"{r['memory_s']:.4g},{r['collective_s']:.4g},{r['dominant']},"
                  f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
                  f"{r['peak_gib']:.2f}")
    return rows


def markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | roofline frac | peak GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['peak_gib']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    table()
