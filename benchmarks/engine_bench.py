"""Continuous-batching engine microbenchmark (data-plane sanity numbers).

Reduced model on CPU: decode step latency vs batch occupancy, prefill
bucket costs, tokens/s, and scheduler behaviour under a burst.  These are
CPU wall-clock numbers for the *real* engine code path — production
performance projections come from the dry-run roofline, not from here.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerConfig


def run(arch: str = "qwen2-0.5b-smoke", n_requests: int = 12,
        capacity: int = 8, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    eng = InferenceEngine(cfg, capacity=capacity, max_len=96, buckets=(16, 32),
                          sched=SchedulerConfig(max_prefill_per_step=2))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(n_requests):
        eng.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 28)))],
            sampling=SamplingParams(max_new_tokens=8, temperature=0.7, top_k=32)))
    done = eng.run(max_steps=500)
    wall = time.perf_counter() - t0

    toks = sum(len(r.output) for r in done)
    decode_times = [s.decode_s for s in eng.history if s.decode_s > 0]
    occ = [s.occupancy for s in eng.history]
    stats = {
        "finished": len(done),
        "tokens": toks,
        "tokens_per_s": toks / wall,
        "decode_p50_ms": 1e3 * float(np.percentile(decode_times, 50)) if decode_times else 0,
        "max_occupancy": max(occ) if occ else 0,
        "mean_ttft_s": float(np.mean([r.ttft for r in done if r.ttft is not None])),
        "steps": len(eng.history),
    }
    if verbose:
        for k, v in stats.items():
            print(f"{k}: {v}")
    assert len(done) == n_requests
    return stats


if __name__ == "__main__":
    run()
