"""Continuous-batching engine microbenchmark (data-plane sanity numbers).

Reduced model on CPU: the *real* engine code path under a bursty arrival
trace mixing short (bucketed) and long (chunked) prompts.  Compares the
batched + chunked prefill pipeline (``max_prefill_per_step >= 2``) against
the one-prefill-per-step baseline: prefill throughput, decode latency,
tokens/s, TTFT.  Both engines are shape-warmed first so the timed section
measures steady-state serving, not XLA compiles.  These are CPU wall-clock
numbers — production performance projections come from the dry-run
roofline, not from here.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerConfig


def _burst_prompts(cfg, rng, n: int, long_every: int = 5) -> list[list[int]]:
    """Mostly short prompts with a long (> largest bucket) one mixed in."""
    prompts = []
    for i in range(n):
        if long_every and i % long_every == long_every - 1:
            ln = int(rng.integers(40, 72))       # chunked-prefill path
        else:
            ln = int(rng.integers(4, 28))        # bucketed path
        prompts.append([int(x) for x in rng.integers(0, cfg.vocab_size, ln)])
    return prompts


def _mk_engine(cfg, mpps: int, capacity: int) -> InferenceEngine:
    return InferenceEngine(
        cfg, capacity=capacity, max_len=96, buckets=(16, 32),
        sched=SchedulerConfig(max_prefill_per_step=mpps))


def _warm(eng, cfg) -> None:
    """Compile every shape the trace will hit: each bucket at the engine's
    group size, the chunk program, and the decode/sampler programs."""
    rng = np.random.default_rng(7)
    rid = 10_000
    for ln in (8, 24, 48):                       # bucket 16, bucket 32, chunked
        for _ in range(eng._group if ln <= 32 else 1):
            eng.submit(Request(rid=rid,
                               prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, ln)],
                               sampling=SamplingParams(max_new_tokens=2,
                                                       temperature=0.7, top_k=32)))
            rid += 1
    eng.run(max_steps=300)
    assert not eng.pending()
    eng.finished.clear()
    eng.history.clear()


def _serve(eng, waves: list[list[list[int]]], max_new: int = 8) -> dict:
    """Waves of burst arrivals: each wave submits all its requests at once
    (worst case for prefill head-of-line blocking), runs until drained."""
    eng.finished = []
    eng.history.clear()
    rid = 0
    t0 = time.perf_counter()
    for wave in waves:
        for p in wave:
            eng.submit(Request(rid=rid, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=max_new,
                                                       temperature=0.7, top_k=32)))
            rid += 1
        eng.run(max_steps=3000)
    wall = time.perf_counter() - t0
    done = eng.finished
    toks = sum(len(r.output) for r in done)
    prompt_toks = sum(s.prefill_tokens for s in eng.history)
    prefill_s = sum(s.prefill_s for s in eng.history)
    decode_times = [s.decode_s for s in eng.history if s.decode_s > 0]
    occ = [s.occupancy for s in eng.history]
    return {
        "finished": len(done),
        "tokens": toks,
        "tokens_per_s": toks / wall,
        "prompt_tokens": prompt_toks,
        "prefill_tok_per_s": prompt_toks / max(prefill_s, 1e-9),
        "prefill_s_total": prefill_s,
        "decode_p50_ms": 1e3 * float(np.percentile(decode_times, 50)) if decode_times else 0,
        "max_occupancy": max(occ) if occ else 0,
        "mean_ttft_s": float(np.mean([r.ttft for r in done if r.ttft is not None])),
        "chunk_steps": sum(1 for s in eng.history if s.chunk_rows),
        "steps": len(eng.history),
        "wall_s": wall,
    }


def run(arch: str = "qwen2-0.5b-smoke", n_requests: int = 24,
        capacity: int = 8, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    rng = np.random.default_rng(0)
    prompts = _burst_prompts(cfg, rng, n_requests)
    waves = [prompts[i:i + 8] for i in range(0, len(prompts), 8)]

    engines = {}
    for label, mpps in (("single", 1), ("pipeline", 4)):
        engines[label] = _mk_engine(cfg, mpps, capacity)
        _warm(engines[label], cfg)

    # single CPU wall-clock runs are noisy; re-measure (warm, no recompiles)
    # before concluding the pipeline lost to the baseline
    for attempt in range(3):
        results = {label: _serve(eng, waves) for label, eng in engines.items()}
        for label in engines:
            assert results[label]["finished"] == n_requests, \
                f"{label}: {results[label]['finished']}/{n_requests} served"
        ratio = (results["pipeline"]["prefill_tok_per_s"]
                 / max(results["single"]["prefill_tok_per_s"], 1e-9))
        if ratio >= 0.95:
            break
    results["prefill_speedup"] = ratio
    if verbose:
        for label in ("single", "pipeline"):
            print(f"--- {label} (max_prefill_per_step="
                  f"{1 if label == 'single' else 4}) ---")
            for k, v in results[label].items():
                print(f"{k}: {v}")
        print(f"prefill_speedup (pipeline/single): {ratio:.2f}x")
    assert ratio >= 0.95, \
        f"batched prefill slower than single-prefill baseline ({ratio:.2f}x)"
    return results


if __name__ == "__main__":
    run()
