"""Continuous-batching engine microbenchmark (data-plane sanity numbers).

Reduced model on CPU: the *real* engine code path under a bursty arrival
trace mixing short (bucketed) and long (chunked) prompts.  Compares the
batched + chunked prefill pipeline (``max_prefill_per_step >= 2``) against
the one-prefill-per-step baseline: prefill throughput, decode latency,
tokens/s, TTFT.  Both engines are shape-warmed first so the timed section
measures steady-state serving, not XLA compiles.  These are CPU wall-clock
numbers — production performance projections come from the dry-run
roofline, not from here.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.configs import get_config
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerConfig


def _burst_prompts(cfg, rng, n: int, long_every: int = 5) -> list[list[int]]:
    """Mostly short prompts with a long (> largest bucket) one mixed in."""
    prompts = []
    for i in range(n):
        if long_every and i % long_every == long_every - 1:
            ln = int(rng.integers(40, 72))       # chunked-prefill path
        else:
            ln = int(rng.integers(4, 28))        # bucketed path
        prompts.append([int(x) for x in rng.integers(0, cfg.vocab_size, ln)])
    return prompts


def _mk_engine(cfg, mpps: int, capacity: int, seed: int = 0) -> InferenceEngine:
    return InferenceEngine(
        cfg, capacity=capacity, max_len=96, buckets=(16, 32),
        sched=SchedulerConfig(max_prefill_per_step=mpps), seed=seed)


def _warm(eng, cfg) -> None:
    """Compile every shape the trace will hit: each bucket at the engine's
    group size, the chunk program, and the decode/sampler programs."""
    rng = np.random.default_rng(7)
    rid = 10_000
    for ln in (8, 24, 48):                       # bucket 16, bucket 32, chunked
        for _ in range(eng._group if ln <= 32 else 1):
            eng.submit(Request(rid=rid,
                               prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, ln)],
                               sampling=SamplingParams(max_new_tokens=2,
                                                       temperature=0.7, top_k=32)))
            rid += 1
    eng.run(max_steps=300)
    assert not eng.pending()
    eng.finished.clear()
    eng.history.clear()


def _serve(eng, waves: list[list[list[int]]], max_new: int = 8) -> dict:
    """Waves of burst arrivals: each wave submits all its requests at once
    (worst case for prefill head-of-line blocking), runs until drained."""
    eng.finished = []
    eng.history.clear()
    rid = 0
    t0 = time.perf_counter()
    for wave in waves:
        for p in wave:
            eng.submit(Request(rid=rid, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=max_new,
                                                       temperature=0.7, top_k=32)))
            rid += 1
        eng.run(max_steps=3000)
    wall = time.perf_counter() - t0
    done = eng.finished
    toks = sum(len(r.output) for r in done)
    prompt_toks = sum(s.prefill_tokens for s in eng.history)
    prefill_s = sum(s.prefill_s for s in eng.history)
    decode_times = [s.decode_s for s in eng.history if s.decode_s > 0]
    occ = [s.occupancy for s in eng.history]
    return {
        "finished": len(done),
        "tokens": toks,
        "tokens_per_s": toks / wall,
        "prompt_tokens": prompt_toks,
        "prefill_tok_per_s": prompt_toks / max(prefill_s, 1e-9),
        "prefill_s_total": prefill_s,
        "decode_p50_ms": 1e3 * float(np.percentile(decode_times, 50)) if decode_times else 0,
        "max_occupancy": max(occ) if occ else 0,
        "mean_ttft_s": float(np.mean([r.ttft for r in done if r.ttft is not None])),
        "chunk_steps": sum(1 for s in eng.history if s.chunk_rows),
        "steps": len(eng.history),
        "wall_s": wall,
    }


def _shared_prefix_prompts(cfg, rng, n: int, prefix_len: int = 48) -> list[list[int]]:
    """Many tenants behind one agent/system template: every prompt shares a
    ``prefix_len``-token system prefix and differs only in a short user tail
    — the dominant multi-tenant serving scenario for prefix caching."""
    system = [int(x) for x in rng.integers(0, cfg.vocab_size, prefix_len)]
    prompts = []
    for _ in range(n):
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size,
                                             int(rng.integers(4, 13)))]
        prompts.append(system + tail)
    return prompts


def run_paged(arch: str = "qwen2-0.5b-smoke", n_requests: int = 24,
              capacity: int = 8, block_size: int = 16,
              seed: int = 0, verbose: bool = True) -> dict:
    """Paged+prefix-cache backend vs. the dense RowPool backend on a
    shared-system-prompt trace: the paged engine must skip the cached prefix
    (hit rate > 0, fewer prompt tokens prefilled) and charge KV per block
    rather than per row."""
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    prompts = _shared_prefix_prompts(cfg, rng, n_requests)
    waves = [prompts[i:i + 8] for i in range(0, len(prompts), 8)]

    engines = {
        "dense": _mk_engine(cfg, 4, capacity, seed),
        "paged": InferenceEngine(
            cfg, capacity=capacity, max_len=96, buckets=(16, 32),
            kv_backend="paged", block_size=block_size,
            sched=SchedulerConfig(max_prefill_per_step=4), seed=seed),
    }
    results: dict = {}
    for label, eng in engines.items():
        _warm(eng, cfg)
        if label == "paged":        # warm-trace pollution out of the stats
            eng.prefix.hit_tokens = eng.prefix.miss_tokens = 0
        results[label] = _serve(eng, waves)
        assert results[label]["finished"] == n_requests, \
            f"{label}: {results[label]['finished']}/{n_requests} served"
        hist = eng.history
        results[label]["prefill_tokens_true"] = sum(
            s.prefill_tokens_true for s in hist)
        results[label]["prefill_tokens_padded"] = sum(
            s.prefill_tokens_padded for s in hist)
        if label == "paged":
            occ_steps = [s for s in hist if s.kv_blocks_used]
            live_tok = sum((1.0 - s.kv_frag) * s.kv_blocks_used * block_size
                           for s in occ_steps)
            blocks = sum(s.kv_blocks_used for s in occ_steps)
            results[label].update({
                "prefix_hit_tokens": sum(s.prefix_hit_tokens for s in hist),
                "prefix_hit_rate": eng.prefix.hit_rate(),
                "blocks_per_token": blocks / max(live_tok, 1e-9),
                "kv_blocks_peak": max((s.kv_blocks_used for s in hist),
                                      default=0),
                "kv_util_peak": max((s.kv_util for s in hist), default=0.0),
                "cow_copies": eng.prefix.cow_copies,
            })
            # dense charges every occupied row its full max_len worth of
            # blocks; the paged peak is what was actually mapped
            dense_equiv = max(s.occupancy for s in hist) * eng.max_blk
            results[label]["dense_equiv_blocks"] = dense_equiv

    pg, dn = results["paged"], results["dense"]
    results["prefill_saved_frac"] = 1.0 - (pg["prefill_tokens_true"]
                                           / max(dn["prefill_tokens_true"], 1))
    if verbose:
        for label in ("dense", "paged"):
            print(f"--- {label} backend ---")
            for k, v in results[label].items():
                print(f"{k}: {v}")
        print(f"prefill tokens saved by prefix cache: "
              f"{100 * results['prefill_saved_frac']:.1f}%")
    assert pg["prefix_hit_rate"] > 0, "shared prefix never hit the cache"
    assert pg["prefill_tokens_true"] < dn["prefill_tokens_true"], \
        "prefix cache did not reduce prefilled tokens"
    assert pg["kv_blocks_peak"] < pg["dense_equiv_blocks"], \
        "paged backend charged no less KV than dense rows"
    return results


def run_migrate(arch: str = "qwen2-0.5b-smoke", n_requests: int = 20,
                capacity: int = 8, block_size: int = 16,
                seed: int = 0, verbose: bool = True) -> dict:
    """Paged scale-down drain: live block-table migration vs. attrition.

    Two paged replicas serve a decaying shared-prefix trace; once arrivals
    stop, replica B is the scale-down victim.  With migration its live
    requests hand their mapped blocks to A (destination-cached prefix
    blocks are skipped — A served the same system prompt); without, B must
    decode every request to completion before it can be reclaimed.  The
    bench reports steps-to-empty for both policies — migration must win,
    it moves O(blocks) bytes instead of running O(remaining tokens) of
    decode — plus the transferred/skipped byte telemetry."""
    from repro.core.migration import MigrationConfig, MigrationManager

    cfg = get_config(arch)
    results: dict = {}
    for policy in ("attrition", "migration"):
        rng = np.random.default_rng(seed)
        prompts = _shared_prefix_prompts(cfg, rng, n_requests)
        # decaying arrivals: big burst first, trailing off to nothing
        waves = []
        i, w = 0, max(n_requests // 2, 1)
        while i < n_requests:
            waves.append(prompts[i:i + w])
            i += w
            w = max(w // 2, 1)

        def mk():
            return InferenceEngine(
                cfg, capacity=capacity, max_len=96, buckets=(16, 32),
                kv_backend="paged", block_size=block_size,
                sched=SchedulerConfig(max_prefill_per_step=4), seed=seed)
        a, b = mk(), mk()
        b.params = a.params
        _warm(a, cfg)
        _warm(b, cfg)
        mgr = MigrationManager(MigrationConfig())
        rid = 0
        for wi, wave in enumerate(waves):        # B takes the decaying tail
            for j, p in enumerate(wave):
                eng = b if (wi + j) % 2 else a
                eng.submit(Request(rid=rid, prompt=list(p),
                                   sampling=SamplingParams(max_new_tokens=24)))
                rid += 1
            # load decays: later (smaller) waves arrive after the earlier
            # ones have mostly drained — the autoscaler's scale-down regime
            for _ in range(6):
                a.step()
                b.step()
        # arrivals over: B is the scale-down victim; hand its queue to A
        while b.scheduler.queue:
            a.submit(b.scheduler.queue.popleft())
        b_tokens_predrain = sum(len(r.output) for r in b.finished)
        drain_steps, t0 = 0, time.perf_counter()
        while (b.pool.used or b.scheduler.depth()) and drain_steps < 2000:
            if policy == "migration":
                # the orchestrator's drain: move everything the survivor
                # will admit, retry the rest next step
                for r in [q.rid for q in b.migratable_requests()]:
                    mgr.migrate(b, a, r, 0.0, 1, 0)
            a.step()
            b.step()
            drain_steps += 1
        drain_s = time.perf_counter() - t0
        a.run(max_steps=3000)                   # A finishes what it absorbed
        served = len(a.finished) + len(b.finished)
        assert served == n_requests, f"{policy}: {served}/{n_requests} served"
        res = {
            "drain_steps": drain_steps,
            "drain_s": drain_s,
            "b_decode_tokens_during_drain": sum(
                len(r.output) for r in b.finished) - b_tokens_predrain,
            "migrated": mgr.succeeded,
            "migration_failures": mgr.failed,
            "bytes_transferred": sum(e.bytes for e in mgr.events),
            "bytes_full": sum(e.bytes_full for e in mgr.events),
            "blocks_skipped": sum(e.blocks_skipped for e in mgr.events),
        }
        a.prefix.check_invariants()
        b.prefix.check_invariants()
        results[policy] = res
    mig, att = results["migration"], results["attrition"]
    results["drain_speedup_steps"] = att["drain_steps"] / max(
        mig["drain_steps"], 1)
    if verbose:
        for policy in ("attrition", "migration"):
            print(f"--- {policy} drain ---")
            for k, v in results[policy].items():
                print(f"{k}: {v}")
        print(f"drain speedup (attrition/migration steps): "
              f"{results['drain_speedup_steps']:.2f}x")
    assert mig["migrated"] > 0, "no request was live-migrated"
    assert mig["drain_steps"] < att["drain_steps"], \
        "live migration did not drain the victim faster than attrition"
    assert mig["bytes_transferred"] <= mig["bytes_full"], \
        "prefix skipping never reduced transfer bytes"
    return results


def _tenant_prompts(cfg, rng, n: int, n_tenants: int = 4,
                    block_size: int = 16,
                    tenant_len: int = 48) -> list[list[int]]:
    """Hierarchical multi-tenant trace: every prompt opens with the same
    one-block platform preamble, continues with one of ``n_tenants``
    tenant-specific agent templates, and ends in a short per-request user
    tail.  First-block affinity routing cannot tell tenants apart (the
    first block is identical for all of them); a cluster cache directory
    walking beyond the first block can."""
    preamble = [int(x) for x in rng.integers(0, cfg.vocab_size, block_size)]
    tenants = [preamble + [int(x) for x in
                           rng.integers(0, cfg.vocab_size, tenant_len)]
               for _ in range(n_tenants)]
    prompts = []
    for _ in range(n):
        t = int(rng.integers(0, n_tenants))
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size,
                                             int(rng.integers(4, 13)))]
        prompts.append(tenants[t] + tail)
    return prompts


def run_directory(arch: str = "qwen2-0.5b-smoke", n_requests: int = 48,
                  capacity: int = 8, block_size: int = 16,
                  seed: int = 0, verbose: bool = True,
                  strict: bool = True) -> dict:
    """Cluster cache directory vs. first-block prefix affinity vs. p2c on a
    multi-tenant trace under autoscaling churn.

    All prompts share a one-block platform preamble; each tenant adds a
    two-block agent template.  The ``"prefix"`` policy keys on the first
    block only, so every tenant rendezvous-hashes to the *same* replica and
    the load guard scatters the overflow blindly; ``"directory"`` walks the
    cluster radix view across the whole prompt and routes each tenant to
    the replica that actually caches its template — including after
    scale-down moved those blocks via migration donation.  Time is the
    logical step clock, so routing, scaling, and the reported metrics are
    seed-deterministic (no wall-clock in the control path)."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig

    cfg = get_config(arch)
    results: dict = {}
    for policy in ("p2c", "prefix", "directory"):
        rng = np.random.default_rng(seed)
        prompts = _tenant_prompts(cfg, rng, n_requests,
                                  block_size=block_size)

        def mk():
            return InferenceEngine(
                cfg, capacity=capacity, max_len=96, buckets=(16, 32),
                kv_backend="paged", block_size=block_size,
                sched=SchedulerConfig(max_prefill_per_step=4), seed=seed)

        ocfg = OrchestratorConfig(
            min_replicas=2, max_replicas=4, lb_policy=policy, lb_seed=seed,
            hpa=HPAConfig(metric="queue", target=2.0, min_replicas=2,
                          max_replicas=4, stabilization_s=8.0,
                          scale_down_cooldown_s=8.0),
            control_every_steps=2)
        orch = Orchestrator(mk, ocfg)

        # churn plan: (requests this burst, arrival rate per step, idle
        # steps after).  Sustained bursts push queue depth over the HPA
        # target (scale up) and keep every replica busy enough that the
        # load guard must spill — where tenant-aware spilling pays; the
        # long lulls drain to nothing (scale down -> drain migration
        # donates the victim's blocks); the second burst then probes
        # whether the policy can still find the surviving warm replicas.
        half = n_requests // 2
        plan = [(half, 6, 40), (n_requests - half, 6, 40)]
        t, rid = 0.0, 0
        for n_burst, rate, idle in plan:
            left = n_burst
            while left > 0:
                for _ in range(min(rate, left)):
                    orch.submit(Request(rid=rid,
                                        prompt=list(prompts[rid]),
                                        sampling=SamplingParams(
                                            max_new_tokens=8)),
                                now=t)
                    rid += 1
                left -= min(rate, left)
                orch.step(now=t)
                t += 1.0
            for _ in range(idle):
                orch.step(now=t)
                t += 1.0
        while orch.pending() and t < 5000.0:
            orch.step(now=t)
            t += 1.0
        done = list(orch.finished)
        for e in orch.engines:
            done.extend(e.finished)
            e.prefix.check_invariants()
        assert len(done) == n_requests, \
            f"{policy}: {len(done)}/{n_requests} served"
        hit = sum(r.prefix_hit_tokens for r in done)
        ptoks = sum(len(r.prompt) for r in done)
        replicas = [n for _, n in orch.scale_history]
        res = {
            "cluster_hit_rate": hit / max(ptoks, 1),
            "prefix_hit_tokens": hit,
            "prompt_tokens": ptoks,
            # what the cluster actually prefilled: prompt tokens minus the
            # ones served straight from replica prefix caches
            "prefill_tokens_true": ptoks - hit,
            "mean_ttft_steps": float(np.mean([r.ttft for r in done])),
            "p90_ttft_steps": float(np.percentile([r.ttft for r in done], 90)),
            "migrations": orch.migrations.succeeded,
            "scale_events": len(orch.scale_history),
            "replicas_peak": max(replicas, default=2),
            "replicas_final": len(orch.engines),
            "directory_entries_final": orch.directory.total_entries,
            "directory_stale_dropped": orch.directory.stats.stale_dropped,
            "steps": t,
        }
        results[policy] = res
    dirp, pref, p2c = (results[p] for p in ("directory", "prefix", "p2c"))
    results["hit_rate_gain_vs_prefix"] = (dirp["cluster_hit_rate"]
                                          - pref["cluster_hit_rate"])
    results["prefill_saved_vs_prefix"] = 1.0 - (
        dirp["prefill_tokens_true"] / max(pref["prefill_tokens_true"], 1))
    if verbose:
        for policy in ("p2c", "prefix", "directory"):
            print(f"--- {policy} routing ---")
            for k, v in results[policy].items():
                print(f"{k}: {v}")
        print(f"hit-rate gain (directory - prefix): "
              f"{results['hit_rate_gain_vs_prefix']:.3f}")
        print(f"prefill tokens saved vs prefix: "
              f"{100 * results['prefill_saved_vs_prefix']:.1f}%")
    # sanity checks are *collected*, not asserted mid-flight: __main__ must
    # still write the metrics JSON on a failing run (the regression gate's
    # re-baselining workflow needs the numbers to diagnose / re-commit)
    checks = [
        (dirp["replicas_peak"] > 2 and dirp["replicas_final"] <= 3,
         "the trace never exercised autoscaling churn"),
        (dirp["cluster_hit_rate"] > pref["cluster_hit_rate"],
         "directory routing did not beat first-block prefix affinity"),
        (dirp["prefill_tokens_true"] < pref["prefill_tokens_true"],
         "directory routing did not reduce prefilled tokens"),
        (dirp["cluster_hit_rate"] > p2c["cluster_hit_rate"],
         "directory routing did not beat p2c"),
    ]
    results["check_failures"] = [msg for ok, msg in checks if not ok]
    if strict and results["check_failures"]:
        raise AssertionError("; ".join(results["check_failures"]))
    return results


def run_transport(arch: str = "qwen2-0.5b-smoke", n_requests: int = 16,
                  capacity: int = 8, block_size: int = 16,
                  seed: int = 0, verbose: bool = True,
                  strict: bool = True) -> dict:
    """Both planes over the simulated cluster transport (core/transport.py).

    Part A — data plane: a scale-down drain on a bandwidth-limited link (one
    KV block per step).  ``stopcopy`` ships each migration as one synchronous
    whole-payload copy that stalls both endpoints for the copy's
    serialization steps; ``overlap`` streams block-granular chunks with
    ``migrate_async`` while *both* replicas keep stepping — the destination
    activates each row the step its last chunk lands.  Overlap must drain
    the victim in fewer steps: the transfer hides behind compute instead of
    adding to it.

    Part B — control plane: the cluster cache directory fed over the same
    fabric, lossless vs. injected faults (drop 30%, reorder 20%, duplicate
    10% on the unreliable delta class).  Directory routing runs on the stale
    *delivered* view; periodic anti-entropy reconciliation repairs the
    losses, so the lossy cluster hit rate must stay within 10% of lossless.

    Everything gated runs on the logical step clock with seeded RNGs (fault
    schedules included), so the metrics are bit-reproducible for a pinned
    ``--seed``."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.migration import MigrationConfig, MigrationManager
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.core.transport import FaultSpec, LinkSpec, Transport

    cfg = get_config(arch)
    results: dict = {}

    def mk():
        return InferenceEngine(
            cfg, capacity=capacity, max_len=96, buckets=(16, 32),
            kv_backend="paged", block_size=block_size,
            sched=SchedulerConfig(max_prefill_per_step=4), seed=seed)

    # --- Part A: drain a victim replica, stop-and-copy vs overlapped ------
    for policy in ("stopcopy", "overlap"):
        rng = np.random.default_rng(seed)
        prompts = _shared_prefix_prompts(cfg, rng, n_requests)
        a, b = mk(), mk()
        b.params = a.params
        _warm(a, cfg)
        _warm(b, cfg)
        bw = float(a.kv_per_block_bytes())    # link fits one block per step
        mgr = MigrationManager(MigrationConfig())
        tp = Transport(LinkSpec(latency_steps=1, bandwidth=bw,
                                max_in_flight=64))
        # the survivor takes a light share, the victim the heavy share: the
        # drain must move live KV, not just requeue cold prompts
        for rid, p in enumerate(prompts):
            eng = a if rid % 4 == 0 else b
            eng.submit(Request(rid=rid, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=24)))
        for _ in range(4):                    # land prefills -> migratable
            a.step()
            b.step()
        # victim's cold queue is control-plane traffic, not a KV transfer
        while b.scheduler.queue:
            a.submit(b.scheduler.queue.popleft())
        drain_steps, stall_steps = 0, 0
        while ((b.pool.used or b.scheduler.depth()
                or mgr.transfers_in_flight) and drain_steps < 2000):
            now = float(drain_steps)
            for rid2 in [q.rid for q in b.migratable_requests()]:
                if policy == "stopcopy":
                    n0 = len(mgr.events)
                    mgr.migrate(b, a, rid2, now, 1, 0)
                    for ev in mgr.events[n0:]:
                        # synchronous copy: both endpoints stall for the
                        # link-serialization steps of the bytes moved
                        stall = int(np.ceil(ev.bytes / bw))
                        drain_steps += stall
                        stall_steps += stall
                else:
                    mgr.migrate_async(b, a, rid2, now, tp, "nb", "na", 1, 0)
            a.step()
            b.step()
            if policy == "overlap":
                mgr.pump(now, tp)
                tp.step()
            drain_steps += 1
        a.run(max_steps=3000)
        b.run(max_steps=3000)
        served = len(a.finished) + len(b.finished)
        assert served == n_requests, f"{policy}: {served}/{n_requests} served"
        a.prefix.check_invariants()
        b.prefix.check_invariants()
        res = {
            "drain_steps": drain_steps,
            "stall_steps": stall_steps,
            "migrated": mgr.succeeded,
            "migration_failures": mgr.failed,
            "bytes_transferred": sum(e.bytes for e in mgr.events),
            "bytes_full": sum(e.bytes_full for e in mgr.events),
            "chunks": sum(e.chunks for e in mgr.events),
            "blocks_skipped": sum(e.blocks_skipped for e in mgr.events),
        }
        if policy == "overlap":
            res["transport_delivered"] = tp.counts["delivered"]
            res["transport_bytes"] = tp.bytes_delivered
        results[policy] = res
    results["overlap_speedup_steps"] = (
        results["stopcopy"]["drain_steps"]
        / max(results["overlap"]["drain_steps"], 1))

    # --- Part B: directory over a lossy fabric vs. lossless ---------------
    dir_res: dict = {}
    for label, faults in (
            ("lossless", FaultSpec()),
            ("lossy", FaultSpec(drop=0.3, reorder=0.2, duplicate=0.1,
                                seed=seed))):
        rng = np.random.default_rng(seed)
        prompts = _tenant_prompts(cfg, rng, 48, block_size=block_size)
        tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf"),
                                max_in_flight=10_000), faults)
        ocfg = OrchestratorConfig(
            min_replicas=2, max_replicas=4, lb_policy="directory",
            lb_seed=seed,
            hpa=HPAConfig(metric="queue", target=2.0, min_replicas=2,
                          max_replicas=4, stabilization_s=8.0,
                          scale_down_cooldown_s=8.0),
            control_every_steps=2, transport=tp)
        orch = Orchestrator(mk, ocfg)
        plan = [(24, 6, 40), (24, 6, 40)]     # run_directory's churn plan
        t, rid = 0.0, 0
        for n_burst, rate, idle in plan:
            left = n_burst
            while left > 0:
                for _ in range(min(rate, left)):
                    orch.submit(Request(rid=rid, prompt=list(prompts[rid]),
                                        sampling=SamplingParams(
                                            max_new_tokens=8)),
                                now=t)
                    rid += 1
                left -= min(rate, left)
                orch.step(now=t)
                t += 1.0
            for _ in range(idle):
                orch.step(now=t)
                t += 1.0
        while orch.pending() and t < 5000.0:
            orch.step(now=t)
            t += 1.0
        done = list(orch.finished)
        for e in orch.engines:
            done.extend(e.finished)
            e.prefix.check_invariants()
        assert len(done) == rid, f"{label}: {len(done)}/{rid} served"
        hit = sum(r.prefix_hit_tokens for r in done)
        ptoks = sum(len(r.prompt) for r in done)
        dir_res[label] = {
            "cluster_hit_rate": hit / max(ptoks, 1),
            "prefix_hit_tokens": hit,
            "prompt_tokens": ptoks,
            "migrations": orch.migrations.succeeded,
            "transport_sent": tp.counts["sent"],
            "transport_delivered": tp.counts["delivered"],
            "transport_dropped": tp.counts["dropped"],
            "transport_duplicated": tp.counts["duplicated"],
            "transport_reordered": tp.counts["reordered"],
            "directory_stale_ignored": orch._dir_service.stale_ignored,
            "steps": t,
        }
    dir_res["hit_ratio"] = (
        dir_res["lossy"]["cluster_hit_rate"]
        / max(dir_res["lossless"]["cluster_hit_rate"], 1e-9))
    results["directory"] = dir_res

    if verbose:
        for policy in ("stopcopy", "overlap"):
            print(f"--- {policy} drain ---")
            for k, v in results[policy].items():
                print(f"{k}: {v}")
        print(f"overlap speedup (stopcopy/overlap steps): "
              f"{results['overlap_speedup_steps']:.2f}x")
        for label in ("lossless", "lossy"):
            print(f"--- directory over transport: {label} ---")
            for k, v in dir_res[label].items():
                print(f"{k}: {v}")
        print(f"lossy/lossless hit ratio: {dir_res['hit_ratio']:.3f}")
    ov, sc = results["overlap"], results["stopcopy"]
    checks = [
        (ov["migrated"] > 0, "no request streamed over the transport"),
        (ov["drain_steps"] < sc["drain_steps"],
         "overlapped streaming did not drain faster than stop-and-copy"),
        (ov["chunks"] >= ov["migrated"],
         "async transfers were not block-granular"),
        (dir_res["lossy"]["transport_dropped"] > 0,
         "the lossy run injected no loss — the fault schedule is dead"),
        (dir_res["hit_ratio"] >= 0.9,
         "directory hit rate under injected loss fell more than 10% "
         "below lossless"),
    ]
    results["check_failures"] = [msg for ok, msg in checks if not ok]
    if strict and results["check_failures"]:
        raise AssertionError("; ".join(results["check_failures"]))
    return results


def _poisson_trace(cfg, rng, n: int, qps: float,
                   interactive_frac: float = 0.7) -> list[dict]:
    """Open-loop arrival spec on the logical step clock: Poisson arrivals at
    ``qps`` requests per step, two SLO classes — interactive (short prompt,
    tight TTFT deadline) and batch (long chunked prompt, loose deadline)."""
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n))
    spec = []
    for i in range(n):
        interactive = bool(rng.random() < interactive_frac)
        ln = int(rng.integers(4, 28)) if interactive \
            else int(rng.integers(40, 72))
        spec.append({
            "arrival": float(arrivals[i]),
            "prompt": [int(x) for x in rng.integers(0, cfg.vocab_size, ln)],
            "interactive": interactive,
        })
    return spec


def _mk_stream_reqs(spec: list[dict]) -> list:
    """Materialise fresh Request objects from a trace spec (requests are
    mutated by serving, so every engine run gets its own copies)."""
    reqs = []
    for i, s in enumerate(spec):
        tight = s["interactive"]
        r = Request(rid=i, prompt=list(s["prompt"]),
                    sampling=SamplingParams(
                        max_new_tokens=8 if tight else 16),
                    slo_ttft=12.0 if tight else 120.0,
                    slo_tpot=3.0 if tight else 6.0)
        r.arrival = s["arrival"]
        reqs.append(r)
    return reqs


def _stream_sweep(eng, reqs: list, n_total: int,
                  max_steps: float = 5000.0) -> dict:
    """Open-loop serve: submit each request at its arrival step, stream
    every token through the event demux, report per-request latency
    percentiles and SLO goodput.  All metrics live on the logical step
    clock, so a pinned seed makes them bit-reproducible (CI gates them)."""
    from repro.serving import FirstTokenEvent, State, StreamDemux

    eng.finished = []
    eng.history.clear()
    demux = StreamDemux()
    streamed: dict[int, list[int]] = {}
    first: dict[int, float] = {}
    i, t, qpeak, preempts = 0, 0.0, 0, 0
    while (i < len(reqs) or eng.pending()) and t < max_steps:
        while i < len(reqs) and reqs[i].arrival <= t:
            eng.submit(reqs[i], now=t)
            i += 1
        st = eng.step(now=t)
        preempts += st.preempted
        qpeak = max(qpeak, st.queue_depth)
        for ev in st.events:
            if isinstance(ev, FirstTokenEvent):
                first[ev.rid] = ev.t
        for tok in demux.feed(st.events):
            streamed.setdefault(tok.rid, []).append(tok.token)
        t += 1.0
    done = eng.finished
    rejected = [r for r in reqs if r.state is State.REJECTED]
    ttfts = sorted(first[r.rid] - r.arrival for r in done if r.rid in first)
    tpots = [r.tpot for r in done if r.tpot is not None]

    def pct(xs, p):
        return float(np.percentile(xs, p)) if xs else 0.0

    return {
        "served": len(done),
        "rejected": len(rejected),
        "tokens": sum(len(r.output) for r in done),
        "ttft_p50_steps": pct(ttfts, 50),
        "ttft_p90_steps": pct(ttfts, 90),
        "ttft_p99_steps": pct(ttfts, 99),
        "tpot_p50_steps": pct(tpots, 50),
        "tpot_p90_steps": pct(tpots, 90),
        "slo_goodput": sum(1 for r in done if r.slo_met()) / max(n_total, 1),
        "queue_peak": qpeak,
        "preemptions": preempts,
        "steps": t,
        "stream_equal": sum(1 for r in done
                            if streamed.get(r.rid, []) == r.output),
    }


def run_stream(arch: str = "qwen2-0.5b-smoke", n_requests: int = 32,
               capacity: int = 8, seed: int = 0, verbose: bool = True,
               strict: bool = True,
               qps_list: tuple[float, ...] = (0.5, 1.5, 3.0),
               trace: bool = False, trace_out: str | None = None,
               metrics_out: str | None = None) -> dict:
    """Open-loop streaming bench: Poisson arrivals swept to saturation.

    A mixed interactive/batch trace (70% short prompts with tight TTFT
    SLOs, 30% long chunked prompts with loose ones) arrives at increasing
    QPS on the logical step clock.  Every token is consumed through the
    typed event stream (the completions front-end's data path) and checked
    byte-identical against the final ``Request.output``.  The EDF scheduler
    (policy="slo", with the decode-pressure guard armed) is swept across
    all rates; FCFS serves the same top-rate trace for the goodput
    comparison — under overload, deadline ordering should keep more
    interactive requests inside their TTFT budget."""
    cfg = get_config(arch)
    results: dict = {}
    traces = {}
    for qps in qps_list:
        rng = np.random.default_rng([seed, int(round(qps * 10))])
        traces[qps] = _poisson_trace(cfg, rng, n_requests, qps)

    # tracing is observational only: the engines always carry a tracer, so
    # --trace merely shares one across sweeps and exports it — the gated
    # serving metrics are bit-identical with it on or off
    tracer = registry = None
    if trace or trace_out or metrics_out:
        from repro.core.metrics import MetricsRegistry
        from repro.core.tracing import Tracer, attribute_slo_misses
        tracer, registry = Tracer(), MetricsRegistry()

    def mk(policy):
        eng = InferenceEngine(
            cfg, capacity=capacity, max_len=96, buckets=(16, 32),
            sched=SchedulerConfig(policy=policy, max_prefill_per_step=4,
                                  slo_guard=(policy == "slo")),
            seed=seed, tracer=tracer, metrics=registry)
        if registry is not None:    # label the two engines apart
            eng.lb_id = {"slo": 0, "fcfs": 1}[policy]
            eng.set_metrics(registry)
        return eng

    # rids 0..n are reused by every sweep, so SLO-miss attribution must be
    # pulled from the live traces sweep-by-sweep, before the next sweep's
    # start_trace archives them
    attribution: list[dict] = []

    def _attribute(key, reqs_run):
        if tracer is None:
            return
        for row in attribute_slo_misses(tracer, reqs_run):
            row["sweep"] = key
            attribution.append(row)

    edf = mk("slo")
    _warm(edf, cfg)
    eq, total_served = 0, 0
    for qps in qps_list:
        key = f"qps_{qps}".replace(".", "p")
        reqs_run = _mk_stream_reqs(traces[qps])
        res = _stream_sweep(edf, reqs_run, n_requests)
        _attribute(key, reqs_run)
        eq += res["stream_equal"]
        total_served += res["served"]
        results[key] = res
    top = qps_list[-1]
    fcfs = mk("fcfs")
    _warm(fcfs, cfg)
    reqs_run = _mk_stream_reqs(traces[top])
    res = _stream_sweep(fcfs, reqs_run, n_requests)
    _attribute(f"fcfs_qps_{top}".replace(".", "p"), reqs_run)
    eq += res["stream_equal"]
    total_served += res["served"]
    results[f"fcfs_qps_{top}".replace(".", "p")] = res

    top_key = f"qps_{top}".replace(".", "p")
    results["stream_equal_frac"] = eq / max(total_served, 1)
    results["goodput_gain_vs_fcfs"] = (results[top_key]["slo_goodput"]
                                       - res["slo_goodput"])
    if verbose:
        for qps in qps_list:
            key = f"qps_{qps}".replace(".", "p")
            print(f"--- edf @ {qps} req/step ---")
            for k, v in results[key].items():
                print(f"{k}: {v}")
        print(f"--- fcfs @ {top} req/step ---")
        for k, v in res.items():
            print(f"{k}: {v}")
        print(f"stream == output for {eq}/{total_served} requests")
        print(f"goodput gain (edf - fcfs) at {top} req/step: "
              f"{results['goodput_gain_vs_fcfs']:.3f}")
    checks = [
        (results["stream_equal_frac"] == 1.0,
         "streamed tokens diverged from Request.output"),
        (all(results[f"qps_{q}".replace('.', 'p')]["served"]
             + results[f"qps_{q}".replace('.', 'p')]["rejected"]
             == n_requests for q in qps_list),
         "requests lost (served + rejected != submitted)"),
        (results["goodput_gain_vs_fcfs"] >= 0.0,
         "EDF scheduling lost goodput to FCFS under overload"),
    ]
    if tracer is not None:
        from repro.core.tracing import format_attribution
        results["slo_miss_attribution"] = attribution
        results["trace_errors"] = tracer.verify()
        checks.append((not results["trace_errors"],
                       "trace integrity violated: "
                       + "; ".join(results["trace_errors"][:3])))
        if verbose:
            print(format_attribution(attribution))
        if trace_out:
            tracer.write_chrome_trace(trace_out)
            print(f"wrote {trace_out} "
                  f"({sum(1 for _ in tracer.traces())} traces)")
        if metrics_out:
            with open(metrics_out, "w") as f:
                f.write(registry.render())
            print(f"wrote {metrics_out}")
    results["check_failures"] = [msg for ok, msg in checks if not ok]
    if strict and results["check_failures"]:
        raise AssertionError("; ".join(results["check_failures"]))
    return results


def _jain(xs: list[float]) -> float:
    """Jain's fairness index over per-tenant (weight-normalized) service:
    1.0 = perfectly weight-proportional shares, 1/n = one tenant hogging."""
    s, s2 = sum(xs), sum(x * x for x in xs)
    return (s * s) / (len(xs) * s2) if s2 > 0 else 1.0


def run_multimodel(arch: str = "qwen2-0.5b-smoke", n_requests: int = 36,
                   capacity: int = 8, seed: int = 0, verbose: bool = True,
                   strict: bool = True) -> dict:
    """Multi-model registry under a tenant-skewed trace: a base endpoint
    (weighted-fair two-tenant admission) plus a scale-to-zero draft
    endpoint that cold-starts twice — once mid-burst, once after idling
    back to zero.

    Entirely on the logical step clock, so served counts, cold-start
    steps, TTFTs, and the fairness index are seed-deterministic.  Tenant
    "alpha" submits 3x tenant "beta"'s volume and holds 3x its weight, so
    weighted-fair shares should match demand: the Jain index over
    weight-normalized mid-burst served tokens is ~1.0 when the wfq policy
    honors the weights (FCFS interleaving also lands near 1.0 here — the
    wfq-specific share test lives in tests/test_endpoints.py; the bench
    gates that fairness never *regresses*)."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.endpoints import (EndpointRegistry, ModelEndpoint,
                                      TenantQuota)
    from repro.serving import State

    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    weights = {"alpha": 3.0, "beta": 1.0}
    reg = EndpointRegistry(
        [ModelEndpoint(
            name="base", model=cfg, capacity=capacity, max_len=96,
            buckets=(16, 32), priority=1, min_replicas=1, max_replicas=2,
            cold_start_steps=0, seed=seed,
            hpa=HPAConfig(metric="queue", target=6.0, max_replicas=2,
                          stabilization_s=8.0, scale_down_cooldown_s=8.0),
            sched=SchedulerConfig(policy="wfq", tenant_weights=weights,
                                  max_prefill_per_step=4)),
         ModelEndpoint(
            name="draft", model=cfg, capacity=4, max_len=96,
            buckets=(16, 32), priority=0, min_replicas=0, max_replicas=1,
            cold_start_steps=4, idle_ticks_to_zero=3,
            control_every_steps=2, seed=seed + 1)],
        tenants={t: TenantQuota(weight=w) for t, w in weights.items()})

    def _prompt():
        return [int(x) for x in rng.integers(0, cfg.vocab_size,
                                             int(rng.integers(8, 17)))]

    n_draft = 4
    n_base = n_requests - n_draft
    base_reqs: list[Request] = []
    draft_reqs: list[Request] = []
    rid = 0

    def _submit_draft(t: float, k: int) -> None:
        nonlocal rid
        for _ in range(k):
            r = Request(rid=rid, model="draft", tenant="alpha",
                        prompt=_prompt(),
                        sampling=SamplingParams(max_new_tokens=6),
                        slo_ttft=20.0, slo_tpot=4.0)
            rid += 1
            draft_reqs.append(r)
            reg.submit(r, now=t)

    # saturating burst on base: 4 submissions per step, 3 alpha : 1 beta;
    # the first draft pair lands mid-burst (cold start #1 overlaps load)
    t, submitted = 0.0, 0
    while submitted < n_base:
        for _ in range(min(4, n_base - submitted)):
            tenant = "beta" if submitted % 4 == 3 else "alpha"
            r = Request(rid=rid, model="base", tenant=tenant,
                        prompt=_prompt(),
                        sampling=SamplingParams(max_new_tokens=8),
                        slo_ttft=30.0, slo_tpot=4.0)
            rid += 1
            submitted += 1
            base_reqs.append(r)
            reg.submit(r, now=t)
        if t == 2.0:
            _submit_draft(t, 2)
        reg.step(t)
        t += 1.0
    while reg.pending() and t < 3000.0:
        reg.step(t)
        t += 1.0
    # idle: the draft endpoint must scale back to zero...
    for _ in range(20):
        reg.step(t)
        t += 1.0
    zero_after_burst = reg.state("draft") == "scaled_to_zero"
    # ...then cold-start again on the next request (wakeup #2)
    _submit_draft(t, 2)
    while reg.pending() and t < 3000.0:
        reg.step(t)
        t += 1.0
    for _ in range(20):
        reg.step(t)
        t += 1.0

    done = reg.finished()
    m = reg.metrics
    # mid-burst weighted fairness: tokens each tenant had streamed by the
    # median base token time, normalized by weight (both tenants are
    # backlogged there, so shares reflect admission policy, not demand)
    tok_times = sorted(tt for r in base_reqs for tt in r.token_times)
    t_cut = tok_times[len(tok_times) // 2] if tok_times else 0.0
    share = {tenant: sum(sum(1 for tt in r.token_times if tt <= t_cut)
                         for r in base_reqs if r.tenant == tenant)
             / weights[tenant] for tenant in weights}
    fairness = _jain(list(share.values()))

    def _ep_res(name: str, reqs: list) -> dict:
        served = [r for r in reqs if r.state is State.DONE]
        return {
            "served": len(served),
            "slo_goodput": (sum(1 for r in served if r.slo_met())
                            / max(len(served), 1)),
            "mean_ttft_steps": float(np.mean([r.ttft for r in served]))
            if served else 0.0,
            "replicas_final": len(reg.resolve(name).engines),
        }

    results: dict = {"base": _ep_res("base", base_reqs),
                     "draft": _ep_res("draft", draft_reqs)}
    results["draft"].update(
        cold_starts=m.get("endpoint_cold_starts_total").value(
            endpoint="draft"),
        cold_start_steps=m.get("endpoint_cold_start_steps").value(
            endpoint="draft"),
        zero_after_burst=zero_after_burst)
    results["tenant_fairness_jain"] = fairness
    results["tenant_share_per_weight"] = share
    results["steps"] = t
    if verbose:
        for name in ("base", "draft"):
            print(f"--- endpoint {name} ---")
            for k, v in results[name].items():
                print(f"{k}: {v}")
        print(f"tenant_fairness_jain: {fairness:.3f} (shares/weight {share})")
    checks = [
        (len(done) == n_requests,
         f"served {len(done)}/{n_requests}"),
        (results["draft"]["cold_starts"] == 2,
         "draft endpoint did not cold-start twice"),
        (zero_after_burst and results["draft"]["replicas_final"] == 0,
         "draft endpoint did not scale back to zero when idle"),
        (fairness >= 0.85,
         f"weighted tenant shares unfair (jain {fairness:.3f})"),
        (results["base"]["slo_goodput"] >= 0.5,
         "base endpoint goodput collapsed"),
    ]
    results["check_failures"] = [msg for ok, msg in checks if not ok]
    if strict and results["check_failures"]:
        raise AssertionError("; ".join(results["check_failures"]))
    return results


def _proactive_traces(cfg, seed: int) -> dict[str, list[list[tuple]]]:
    """Per-scenario arrival traces on the logical step clock.

    Each trace is a list of steps; each step is a list of
    ``(tenant, prompt, max_new)`` arrivals.  Generated once per scenario
    from a seeded rng and replayed *identically* under both policies, so
    reactive-vs-proactive differences are controller differences, nothing
    else."""
    rng = np.random.default_rng(seed)

    def _req(tenant=None):
        plen = int(rng.integers(8, 17))
        prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, plen)]
        return (tenant, prompt, 8)

    def _trace(lams, tenant=None):
        return [[_req(tenant) for _ in range(int(rng.poisson(lam)))]
                for lam in lams]

    traces: dict[str, list[list[tuple]]] = {}
    # diurnal: two sinusoidal day/night cycles — the forecaster's trend
    # term should ride the upswings instead of waiting for queue build-up
    traces["diurnal"] = _trace(
        [0.15 + 1.0 * 0.5 * (1 + math.sin(2 * math.pi * s / 80 - math.pi / 2))
         for s in range(160)])
    # flash crowd: quiet floor, an 8-step linear ramp, a hot plateau that
    # needs ~max_replicas, then quiet again.  The ramp is the proactive
    # policy's whole case: extrapolate it and be warm when the plateau
    # lands, vs react to the queue the plateau causes
    quiet, hot = 0.1, 2.6
    traces["flash"] = _trace(
        [quiet] * 36
        + [quiet + (hot - quiet) * (i + 1) / 8 for i in range(8)]
        + [hot] * 48 + [quiet] * 28)
    # tenant hotspot: a steady background tenant plus one tenant spiking
    # mid-run — scaling must absorb the hot tenant without dragging the
    # steady tenant's SLOs down with it
    steady = _trace([0.5] * 160, tenant="steady")
    hotspot = _trace([0.0] * 56 + [1.8] * 48 + [0.0] * 56, tenant="hot")
    traces["hotspot"] = [a + b for a, b in zip(steady, hotspot)]
    # replay with churn: an on/off square wave (three bursts, long lulls)
    # that forces the autoscaler up and down repeatedly — the goodput
    # guard must not let scale-down eat the next burst's headroom
    wave = ([2.2] * 20 + [0.08] * 28) * 3
    traces["replay"] = _trace(wave)
    return traces


def _run_proactive_scenario(cfg, trace, policy: str, seed: int, *,
                            capacity: int = 4, cold_start_steps: int = 8,
                            control_every: int = 4,
                            max_replicas: int = 6) -> dict:
    """One scenario under one controller; logical clock throughout."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.core.scaling_policy import ProactiveConfig
    from repro.serving import State

    def mk():
        return InferenceEngine(cfg, capacity=capacity, max_len=64,
                               buckets=(8, 16), seed=seed)

    ocfg = OrchestratorConfig(
        name="bench", min_replicas=1, max_replicas=max_replicas,
        hpa=HPAConfig(metric="queue", target=6.0, min_replicas=1,
                      max_replicas=max_replicas, stabilization_s=16.0,
                      scale_down_cooldown_s=16.0),
        scaling=ProactiveConfig() if policy == "proactive" else None,
        cold_start_steps=cold_start_steps, control_every_steps=control_every)
    orch = Orchestrator(mk, ocfg)

    reqs: list[Request] = []
    t, rid = 0.0, 0
    for arrivals in trace:
        for tenant, prompt, max_new in arrivals:
            r = Request(rid=rid, tenant=tenant, prompt=list(prompt),
                        sampling=SamplingParams(max_new_tokens=max_new),
                        slo_ttft=12.0, slo_tpot=3.0)
            rid += 1
            reqs.append(r)
            orch.submit(r, now=t)
        orch.step(now=t)
        t += 1.0
    while orch.pending() and t < 5000.0:
        orch.step(now=t)
        t += 1.0

    done = [r for r in reqs if r.state is State.DONE]
    assert len(done) == len(reqs), \
        f"{policy}: {len(done)}/{len(reqs)} served"
    ttfts = [r.ttft for r in done]
    ups = [tt for tt, c, nw, _ in orch.autoscaler.decisions if nw > c]
    replicas = [n for _, n in orch.scale_history]
    res = {
        "served": len(done),
        "slo_goodput": sum(1 for r in done if r.slo_met()) / len(done),
        "mean_ttft_steps": float(np.mean(ttfts)),
        "p95_ttft_steps": float(np.percentile(ttfts, 95)),
        "first_scaleup_step": ups[0] if ups else None,
        "scale_events": len(orch.scale_history),
        "replicas_peak": max(replicas, default=1),
        "replicas_final": len(orch.engines),
        "steps": t,
    }
    by_tenant = {r.tenant for r in done if r.tenant}
    if len(by_tenant) > 1:
        for tenant in sorted(by_tenant):
            sub = [r for r in done if r.tenant == tenant]
            res[f"goodput_{tenant}"] = \
                sum(1 for r in sub if r.slo_met()) / len(sub)
    return res


def run_proactive(arch: str = "qwen2-0.5b-smoke", n_requests: int = 0,
                  seed: int = 0, verbose: bool = True,
                  strict: bool = True) -> dict:
    """Proactive goodput-driven autoscaling vs the reactive HPA law across
    four scenarios — diurnal cycle, flash crowd, tenant hotspot, and a
    churn-heavy trace replay — each replayed from an identical seeded
    arrival trace under both controllers (``n_requests`` is ignored: the
    traces fix the workload).

    The headline number is the flash-crowd goodput gain: the proactive
    policy forecasts the ramp at the cold-start horizon and jumps straight
    to ``ceil(demand / learned_capacity)`` replicas, so they are warm when
    the plateau lands; the reactive law waits for queue depth to cross its
    target and then ratchets up one ratio step per control period, paying
    the cold start *inside* the spike.  Also includes the promoted
    deterministic ramp ablation that used to live in
    ``benchmarks/burst_proactive.py``.

    Entirely on the logical step clock: goodputs, TTFT steps, scale-up
    steps, and replica peaks are seed-deterministic and CI-gateable."""
    from burst_proactive import ramp_trigger_times

    cfg = get_config(arch)
    traces = _proactive_traces(cfg, seed)
    results: dict = {"scenarios": {}}
    for name, trace in traces.items():
        row: dict = {}
        for policy in ("reactive", "proactive"):
            row[policy] = _run_proactive_scenario(cfg, trace, policy, seed)
        row["goodput_gain"] = (row["proactive"]["slo_goodput"]
                               - row["reactive"]["slo_goodput"])
        r_up, p_up = (row["reactive"]["first_scaleup_step"],
                      row["proactive"]["first_scaleup_step"])
        row["scaleup_lead_steps"] = \
            (r_up - p_up) if (r_up is not None and p_up is not None) else None
        results["scenarios"][name] = row
    flash = results["scenarios"]["flash"]
    results["flash_goodput_gain"] = flash["goodput_gain"]
    results["flash_scaleup_lead_steps"] = flash["scaleup_lead_steps"]
    results["mean_goodput_gain"] = float(np.mean(
        [row["goodput_gain"] for row in results["scenarios"].values()]))
    # promoted unit ablation: reactive vs forecast trigger time on a clean
    # linear ramp (no queueing dynamics, pure controller lead)
    results["ramp"] = ramp_trigger_times()
    if verbose:
        for name, row in results["scenarios"].items():
            print(f"--- scenario {name} ---")
            for policy in ("reactive", "proactive"):
                r = row[policy]
                print(f"  {policy}: goodput={r['slo_goodput']:.3f} "
                      f"p95_ttft={r['p95_ttft_steps']:.0f} "
                      f"first_up={r['first_scaleup_step']} "
                      f"peak={r['replicas_peak']} "
                      f"events={r['scale_events']}")
            print(f"  goodput_gain={row['goodput_gain']:+.3f} "
                  f"scaleup_lead={row['scaleup_lead_steps']}")
        print(f"flash goodput gain: {results['flash_goodput_gain']:+.3f}; "
              f"ramp lead {results['ramp']['lead_s']:.0f}s")
    checks = [
        (flash["goodput_gain"] > 0,
         f"proactive did not beat reactive goodput on the flash crowd "
         f"({flash['proactive']['slo_goodput']:.3f} vs "
         f"{flash['reactive']['slo_goodput']:.3f})"),
        (flash["scaleup_lead_steps"] is not None
         and flash["scaleup_lead_steps"] > 0,
         "proactive scale-up did not lead reactive on the flash crowd"),
        (results["mean_goodput_gain"] > -0.01,
         "proactive lost goodput on average across the scenario suite"),
        (results["ramp"]["lead_s"] > 0,
         "forecast trigger did not lead reactive on the clean ramp"),
    ]
    results["check_failures"] = [msg for ok, msg in checks if not ok]
    if strict and results["check_failures"]:
        raise AssertionError("; ".join(results["check_failures"]))
    return results


def run(arch: str = "qwen2-0.5b-smoke", n_requests: int = 24,
        capacity: int = 8, seed: int = 0, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    prompts = _burst_prompts(cfg, rng, n_requests)
    waves = [prompts[i:i + 8] for i in range(0, len(prompts), 8)]

    engines = {}
    for label, mpps in (("single", 1), ("pipeline", 4)):
        engines[label] = _mk_engine(cfg, mpps, capacity, seed)
        _warm(engines[label], cfg)

    # single CPU wall-clock runs are noisy; re-measure (warm, no recompiles)
    # before concluding the pipeline lost to the baseline
    for attempt in range(3):
        results = {label: _serve(eng, waves) for label, eng in engines.items()}
        for label in engines:
            assert results[label]["finished"] == n_requests, \
                f"{label}: {results[label]['finished']}/{n_requests} served"
        ratio = (results["pipeline"]["prefill_tok_per_s"]
                 / max(results["single"]["prefill_tok_per_s"], 1e-9))
        if ratio >= 0.95:
            break
    results["prefill_speedup"] = ratio
    if verbose:
        for label in ("single", "pipeline"):
            print(f"--- {label} (max_prefill_per_step="
                  f"{1 if label == 'single' else 4}) ---")
            for k, v in results[label].items():
                print(f"{k}: {v}")
        print(f"prefill_speedup (pipeline/single): {ratio:.2f}x")
    assert ratio >= 0.95, \
        f"batched prefill slower than single-prefill baseline ({ratio:.2f}x)"
    return results


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode",
                    choices=["pipeline", "paged", "migrate", "directory",
                             "stream", "transport", "multimodel",
                             "proactive"],
                    default="pipeline",
                    help="pipeline: batched/chunked prefill vs single-prefill; "
                         "paged: paged+prefix-cache backend vs dense rows; "
                         "migrate: paged scale-down drain, live block-table "
                         "migration vs attrition; directory: cluster "
                         "cache-directory routing vs prefix affinity vs p2c "
                         "under autoscaling churn; stream: open-loop Poisson "
                         "QPS sweep through the per-token event stream, "
                         "TTFT/TPOT percentiles and SLO goodput, EDF vs FCFS; "
                         "transport: both planes over the simulated cluster "
                         "fabric — overlapped block-granular drain vs "
                         "stop-and-copy, directory hit rate under injected "
                         "loss vs lossless; multimodel: two endpoints behind "
                         "one registry — wfq tenant fairness on the base "
                         "model, scale-to-zero cold starts on the draft "
                         "model, priority-aware replica budget; "
                         "proactive: goodput-driven forecast scaling vs "
                         "the reactive HPA law across diurnal / flash-"
                         "crowd / tenant-hotspot / churn-replay scenarios "
                         "on identical seeded traces")
    ap.add_argument("--n", type=int, default=None,
                    help="requests (default: per-mode)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for trace generation and LB/engine "
                         "construction — runs with the same seed are "
                         "bit-reproducible (the CI regression gate pins it)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the result dict as JSON (CI artifact)")
    ap.add_argument("--trace", action="store_true",
                    help="(stream mode) share one request-lifecycle tracer "
                         "and metrics registry across the sweep: writes "
                         "TRACE_stream.json (Chrome/Perfetto trace events) "
                         "and METRICS_stream.prom (Prometheus text "
                         "exposition), prints the SLO-miss attribution table")
    args = ap.parse_args()
    fn = {"paged": run_paged, "migrate": run_migrate,
          "pipeline": run, "directory": run_directory,
          "stream": run_stream, "transport": run_transport,
          "multimodel": run_multimodel, "proactive": run_proactive}[args.mode]
    kwargs = {"seed": args.seed}
    if args.n is not None:
        kwargs["n_requests"] = args.n
    if args.mode in ("directory", "stream", "transport", "multimodel",
                     "proactive"):
        kwargs["strict"] = False     # report failures after writing the json
    if args.mode == "stream" and args.trace:
        kwargs.update(trace=True, trace_out="TRACE_stream.json",
                      metrics_out="METRICS_stream.prom")
    res = fn(**kwargs)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2, default=float)
        print(f"wrote {args.json}")
    if res.get("check_failures"):
        print("BENCH CHECKS FAILED:")
        for msg in res["check_failures"]:
            print(f"  {msg}")
        sys.exit(1)
