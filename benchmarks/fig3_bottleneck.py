"""Paper Fig. 3 — maximum inference latency across 40 Transformer layers.

Runs the calibrated LLaMA-2-13B/3xA100 simulator under concurrent load with
input lengths 50-2048 (the paper's Locust setup) and reports per-layer max
latency.  Expected: strongly right-skewed distribution with Layer 27's max
more than 230x Layer 30's.
"""
from __future__ import annotations

from repro.core.cluster import (ClusterConfig, SimCluster,
                                llama2_13b_a100_costs, poisson_open_loop)


def run(duration_s: float = 2400.0, rate_jobs_s: float = 0.06, batch: int = 32,
        seed: int = 3, verbose: bool = True) -> dict:
    """Open-loop Poisson at ~50% bottleneck utilization: most jobs see an
    idle hotspot (latency near base) while bursts queue up and trip the
    heavy-tail interference — the right-skewed profile of paper Fig. 3."""
    costs = llama2_13b_a100_costs()
    cl = SimCluster(ClusterConfig(seed=seed), costs, hpa=None)
    poisson_open_loop(cl, rate_jobs_s=rate_jobs_s, batch=batch,
                      duration_s=duration_s, seed=seed)

    rows = []
    for i in range(len(cl.services)):
        st = cl.stage_latency_stats(f"layer/{i}")
        rows.append((i, st["max"], st["mean"]))
    mx = {i: m for i, m, _ in rows}
    ratio = mx[27] / mx[30]
    # right-skew over the whole run (the profiler window is 15 s — too short
    # for multi-minute jobs), Fisher skewness of layer-27 latencies
    import math
    vals = [j.stage_latency.get("layer/27") for j in cl.done]
    vals = [v for v in vals if v is not None]
    mean = sum(vals) / len(vals)
    sd = math.sqrt(sum((v - mean) ** 2 for v in vals) / len(vals)) or 1e-12
    skew = sum((v - mean) ** 3 for v in vals) / len(vals) / sd ** 3
    if verbose:
        print("layer,max_latency_s,mean_latency_s")
        for i, m, mean in rows:
            mark = "  <-- bottleneck" if i == 27 else (" <-- fastest" if i == 30 else "")
            print(f"{i},{m:.4f},{mean:.4f}{mark}")
        print(f"\nhotspot ratio layer27/layer30 (max): {ratio:.0f}x  "
              f"(paper: >230x)   right-skew(27): {skew:.2f}")
    return {"ratio": ratio, "max_by_layer": mx, "skew27": skew,
            "jobs": len(cl.done)}


if __name__ == "__main__":
    run()
