"""Benchmark harness — one entry per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run

Emits a summary line per benchmark:  name,value,unit,paper_reference
"""
from __future__ import annotations

import sys
import time


def main() -> int:
    from benchmarks import engine_bench, fig3_bottleneck, fig4_autoscaling, roofline

    print("=" * 72)
    print("FIG 3 — per-layer bottleneck identification (calibrated sim)")
    print("=" * 72)
    t0 = time.time()
    f3 = fig3_bottleneck.run(verbose=True)
    print(f"[fig3 took {time.time()-t0:.1f}s]")

    print("\n" + "=" * 72)
    print("FIG 4 — autoscaling latency/throughput sweep (calibrated sim)")
    print("=" * 72)
    t0 = time.time()
    f4 = fig4_autoscaling.run(verbose=True)
    print(f"[fig4 took {time.time()-t0:.1f}s]")

    print("\n" + "=" * 72)
    print("ENGINE — continuous-batching microbench (real JAX engine, CPU)")
    print("=" * 72)
    eng = engine_bench.run(verbose=True)

    print("\n" + "=" * 72)
    print("PREDICTION — proactive-vs-reactive autoscaling ablation")
    print("=" * 72)
    from benchmarks import burst_proactive
    pred = burst_proactive.run(verbose=True)

    print("\n" + "=" * 72)
    print("ROOFLINE — per-cell terms from the dry-run (16x16 mesh)")
    print("=" * 72)
    rows = roofline.table(verbose=True)

    # ------------------------------------------------------------- summary
    print("\n" + "=" * 72)
    print("SUMMARY  name,value,unit,paper_reference")
    print("=" * 72)
    wo = next(r for r in f4 if r["batch"] == 62 and not r["autoscale"])
    w = next(r for r in f4 if r["batch"] == 62 and r["autoscale"])
    print(f"fig3_hotspot_ratio,{f3['ratio']:.0f},x,paper >230x")
    print(f"fig4_latency_wo,{wo['e2e_s']:.2f},s,paper 15.23")
    print(f"fig4_latency_cn,{w['e2e_s']:.2f},s,paper 12.28")
    print(f"fig4_qps_wo,{wo['qps']:.2f},qps,paper 4.07")
    print(f"fig4_qps_cn,{w['qps']:.2f},qps,paper 5.05")
    print(f"engine_tokens_per_s,{eng['tokens_per_s']:.1f},tok/s,(CPU reduced)")
    print(f"proactive_lead,{pred['ramp']['lead_s']:.0f},s,(beyond paper: §3 prediction module)")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        print(f"roofline_cells,{len(rows)},cells,40 minus documented skips")
        print(f"roofline_best,{best['roofline_fraction']:.3f},frac,"
              f"{best['arch']}x{best['shape']}")
        print(f"roofline_worst,{worst['roofline_fraction']:.3f},frac,"
              f"{worst['arch']}x{worst['shape']}")
    else:
        print("roofline_cells,0,cells,run repro.launch.dryrun --all first")
    return 0


if __name__ == "__main__":
    sys.exit(main())
