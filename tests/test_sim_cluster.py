"""Simulator behaviour + paper-figure validation (deliverables c, d)."""
import pytest

from repro.core.cluster import (ClusterConfig, LayerCost, SimCluster, SimJob,
                                closed_loop, llama2_13b_a100_costs,
                                poisson_open_loop)


def _uniform_costs(n=4, alpha=0.1, beta=0.0):
    return [LayerCost(alpha=alpha, beta=beta) for _ in range(n)]


def test_pipeline_latency_additive():
    """One job through N uniform stages: E2E == N * alpha exactly."""
    cl = SimCluster(ClusterConfig(num_layers=4, seed=0), _uniform_costs(4, 0.1))
    cl.submit(SimJob(0, batch=1, tokens=100, t_submit=0.0))
    cl.run(until=10.0)
    assert cl.done and cl.done[0].e2e == pytest.approx(0.4, abs=1e-6)


def test_queueing_under_concurrency():
    """Two simultaneous jobs on one replica: second waits at each stage."""
    cl = SimCluster(ClusterConfig(num_layers=1, seed=0), _uniform_costs(1, 1.0))
    cl.submit(SimJob(0, 1, 100, 0.0))
    cl.submit(SimJob(1, 1, 100, 0.0))
    cl.run(until=30.0)
    e2es = sorted(j.e2e for j in cl.done)
    assert e2es[0] == pytest.approx(1.0, abs=1e-6)
    assert e2es[1] == pytest.approx(2.0, abs=1e-6)


def test_batch_split_speedup():
    """With 2 ready replicas and batch_split, the beta term halves."""
    costs = [LayerCost(alpha=0.1, beta=0.01, split_overhead=0.0)]
    cl = SimCluster(ClusterConfig(num_layers=1, cold_start_s=0.0, seed=0), costs)
    cl.services[0].scale_to(0.0, 2)
    cl.submit(SimJob(0, batch=100, tokens=10, t_submit=1.0))
    cl.run(until=20.0)
    # split: alpha + beta*ceil(100/2) = 0.1 + 0.5 (vs 1.1 unsplit)
    assert cl.done[0].e2e == pytest.approx(0.6, abs=1e-6)


def test_cold_start_delays_replica():
    costs = [LayerCost(alpha=1.0, beta=0.0)]
    cl = SimCluster(ClusterConfig(num_layers=1, cold_start_s=5.0, seed=0), costs)
    cl.services[0].scale_to(0.0, 2)         # replica 1 ready at t=5
    assert len(cl.services[0].ready(1.0)) == 1
    assert len(cl.services[0].ready(6.0)) == 2


def test_failure_injection_reroutes():
    costs = [LayerCost(alpha=0.5, beta=0.0)]
    cl = SimCluster(ClusterConfig(num_layers=1, cold_start_s=0.0, seed=0), costs)
    cl.services[0].scale_to(0.0, 2)
    cl.inject_failure(0.1, 0, 0)
    cl.submit(SimJob(0, 1, 10, t_submit=1.0))
    cl.run(until=10.0)
    assert cl.done and cl.done[0].e2e == pytest.approx(0.5, abs=1e-6)


def test_straggler_slows_then_autoscaler_helps():
    costs = [LayerCost(alpha=1.0, beta=0.0)]
    cl = SimCluster(ClusterConfig(num_layers=1, cold_start_s=0.0, seed=0), costs)
    cl.inject_straggler(0.0, 0, 0, speed=0.25)
    cl.submit(SimJob(0, 1, 10, t_submit=1.0))
    cl.run(until=20.0)
    assert cl.done[0].e2e == pytest.approx(4.0, abs=1e-6)   # 1.0 / 0.25


def test_open_loop_poisson_completes():
    cl = SimCluster(ClusterConfig(num_layers=2, seed=0), _uniform_costs(2, 0.01))
    poisson_open_loop(cl, rate_jobs_s=5.0, batch=4, duration_s=30.0, seed=1)
    assert len(cl.done) > 50
    assert cl.qps() > 0


# ------------------------------------------------------- paper validation
def test_fig4_reproduces_paper_numbers():
    """Batch 62: 15.23s -> 12.28s, 4.07 -> 5.05 QPS (within 5%)."""
    from benchmarks.fig4_autoscaling import run_one
    wo = run_one(62, False, duration_s=600.0)
    w = run_one(62, True, duration_s=600.0)
    assert wo["e2e_s"] == pytest.approx(15.23, rel=0.05)
    assert w["e2e_s"] == pytest.approx(12.28, rel=0.05)
    assert wo["qps"] == pytest.approx(4.07, rel=0.05)
    assert w["qps"] == pytest.approx(5.05, rel=0.05)
    assert w["replicas27"] > 1


def test_fig3_hotspot_exceeds_230x():
    from benchmarks.fig3_bottleneck import run
    res = run(duration_s=1200.0, verbose=False)
    assert res["ratio"] > 230.0
    assert res["skew27"] > 0.5               # right-skewed, as in the paper


def test_autoscaling_never_hurts_throughput():
    from benchmarks.fig4_autoscaling import run_one
    for b in (16, 48):
        wo = run_one(b, False, duration_s=400.0)
        w = run_one(b, True, duration_s=400.0)
        assert w["qps"] >= wo["qps"] * 0.98


def test_proactive_scaling_leads_reactive():
    """Paper §3 load prediction: a Holt-Winters-driven HPA fires ~horizon
    earlier than the reactive controller on a rising load ramp."""
    from benchmarks.burst_proactive import ramp_trigger_times
    r = ramp_trigger_times(horizon_s=60.0)
    assert r["proactive"] is not None and r["reactive"] is not None
    assert r["lead_s"] >= 30.0
