"""Scheduler policy + admission tests."""
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, SchedulerConfig


def _r(rid, prompt_len, arrival, slo_ttft=None):
    return Request(rid=rid, prompt=list(range(prompt_len)), arrival=arrival,
                   slo_ttft=slo_ttft)


def test_fcfs_order():
    s = Scheduler(SchedulerConfig(policy="fcfs", max_prefill_per_step=3))
    for i, t in enumerate([3.0, 1.0, 2.0]):
        s.submit(_r(i, 4, t), now=t)
    picked = s.next_batch(3, now=5.0)
    assert [r.rid for r in picked] == [1, 2, 0]


def test_sjf_prefers_short_prompts():
    s = Scheduler(SchedulerConfig(policy="sjf", max_prefill_per_step=2))
    s.submit(_r(0, 100, 0.0), 0.0)
    s.submit(_r(1, 5, 1.0), 1.0)
    s.submit(_r(2, 50, 2.0), 2.0)
    picked = s.next_batch(2, now=3.0)
    assert [r.rid for r in picked] == [1, 2]


def test_slo_deadline_order():
    s = Scheduler(SchedulerConfig(policy="slo", max_prefill_per_step=2))
    s.submit(_r(0, 4, 0.0, slo_ttft=100.0), 0.0)
    s.submit(_r(1, 4, 1.0, slo_ttft=2.0), 1.0)
    picked = s.next_batch(1, now=1.5)
    assert picked[0].rid == 1


def test_admission_timeout_rejects():
    s = Scheduler(SchedulerConfig(admission_timeout=5.0))
    s.submit(_r(0, 4, 0.0), 0.0)
    s.submit(_r(1, 4, 8.0), 8.0)
    picked = s.next_batch(2, now=10.0)
    assert [r.rid for r in picked] == [1]
    assert s.rejected == 1


def test_queue_capacity_rejects():
    s = Scheduler(SchedulerConfig(max_queue=2))
    assert s.submit(_r(0, 4, 0.0), 0.0)
    assert s.submit(_r(1, 4, 0.0), 0.0)
    assert not s.submit(_r(2, 4, 0.0), 0.0)
    assert s.rejected == 1


def test_respects_free_slots_and_step_cap():
    s = Scheduler(SchedulerConfig(max_prefill_per_step=2))
    for i in range(5):
        s.submit(_r(i, 4, float(i)), float(i))
    assert len(s.next_batch(1, now=9.0)) == 1     # slots bound
    assert len(s.next_batch(4, now=9.0)) == 2     # per-step cap binds
    assert s.depth() == 2
