"""Load-prediction forecasters: the shared observe/forecast contract,
horizon semantics, and robustness properties.

The deterministic contract tests always run; the randomized property
sweeps additionally run under hypothesis when it is installed (same
guard idiom as tests/test_properties.py)."""
import math

import numpy as np
import pytest

from repro.core.predictor import (EWMA, HoltWinters, WindowedAR,
                                  make_predictor)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # property sweeps skip; contract tests still run
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=40, deadline=None)

FORECASTERS = [
    pytest.param(lambda: EWMA(), id="ewma"),
    pytest.param(lambda: HoltWinters(dt=1.0), id="holt"),
    pytest.param(lambda: WindowedAR(dt=1.0), id="ar"),
]


# ------------------------------------------------ shared contract (determ.)
@pytest.mark.parametrize("mk", FORECASTERS)
@pytest.mark.parametrize("horizon", [0.0, 1.0, 7.5, 400.0])
def test_forecast_nonnegative_finite(mk, horizon):
    """Any history of non-negative loads, any horizon: the forecast is a
    finite non-negative number (load forecasts feed ceil(demand/capacity)
    — a nan/inf/negative would poison the replica plan)."""
    rng = np.random.default_rng(3)
    for vals in ([], [0.0], list(rng.uniform(0, 1e6, 50)),
                 [1e6, 0.0] * 20, list(rng.exponential(5.0, 80))):
        p = mk()
        for i, v in enumerate(vals):
            p.observe(float(i), float(v))
        f = p.forecast(horizon)
        assert math.isfinite(f)
        assert f >= 0.0


@pytest.mark.parametrize("mk", FORECASTERS)
@pytest.mark.parametrize("level", [0.0, 3.25, 1e5])
def test_constant_history_forecasts_the_constant(mk, level):
    """A flat signal forecasts (about) itself at every horizon — no
    forecaster invents a trend from a constant."""
    p = mk()
    for i in range(40):
        p.observe(float(i), level)
    for horizon in (1.0, 16.0, 250.0):
        assert p.forecast(horizon) == pytest.approx(level, rel=1e-6,
                                                    abs=1e-6)


@pytest.mark.parametrize("mk", FORECASTERS)
def test_empty_and_short_histories(mk):
    """No observations, or fewer than any model's fit minimum: forecast
    degrades to a finite non-negative value instead of raising."""
    p = mk()
    assert p.forecast(10.0) >= 0.0
    p.observe(0.0, 3.0)
    f = p.forecast(10.0)
    assert math.isfinite(f) and f >= 0.0
    p2 = mk()
    for i in range(3):          # below WindowedAR's order+2 fit minimum
        p2.observe(float(i), float(i))
    f2 = p2.forecast(5.0)
    assert math.isfinite(f2) and f2 >= 0.0


# --------------------------------------------------- Holt-Winters horizon
def test_holt_tracks_linear_ramp_at_any_horizon():
    """On a noiseless linear ramp the level+trend model converges to the
    line; forecast(h) must then extrapolate it: ~ last + slope * h."""
    for slope, intercept in ((0.5, 10.0), (3.0, 0.0), (40.0, 7.0)):
        p = HoltWinters(dt=1.0)
        n = 400
        for i in range(n):
            p.observe(float(i), intercept + slope * i)
        for horizon in (1.0, 12.0, 150.0):
            want = intercept + slope * (n - 1) + slope * horizon
            assert p.forecast(horizon) == pytest.approx(want, rel=0.05,
                                                        abs=1.0)


def test_holt_dt_scales_the_horizon():
    """dt converts seconds to model steps: forecasting 2*dt ahead must
    advance the trend exactly two steps regardless of dt."""
    for dt in (0.5, 1.0, 4.0):
        p = HoltWinters(dt=dt)
        for i in range(200):
            p.observe(i * dt, 2.0 * i)        # +2 per observation
        f1 = p.forecast(dt)
        f2 = p.forecast(2 * dt)
        assert f2 - f1 == pytest.approx(2.0, rel=0.05)


# ------------------------------------------------------ WindowedAR horizon
def test_windowed_ar_forecast_honors_horizon_contract():
    """Regression for the fixed bug: forecast(horizon_s) must roll the
    fitted model ceil(horizon_s / dt) steps forward, not always one.  On a
    deterministic ramp the AR fit is (near-)exact, so the h-step forecast
    must land h steps up the line."""
    p = WindowedAR(order=2, window=64, dt=1.0)
    for i in range(40):
        p.observe(float(i), 5.0 + 3.0 * i)
    last = 5.0 + 3.0 * 39
    for h in (1, 4, 10):
        assert p.forecast(float(h)) == pytest.approx(last + 3.0 * h,
                                                     rel=0.02, abs=0.5)
    # dt != 1: the same wall horizon is fewer model steps
    q = WindowedAR(order=2, window=64, dt=5.0)
    for i in range(40):
        q.observe(5.0 * i, 5.0 + 3.0 * i)
    assert q.forecast(10.0) == pytest.approx(last + 3.0 * 2, rel=0.02,
                                             abs=0.5)
    # explicit steps override bypasses the dt conversion
    assert q.forecast(steps=4) == pytest.approx(last + 3.0 * 4, rel=0.02,
                                                abs=0.5)


def _ar_series(coeffs, c, n=120, seed=0):
    p = len(coeffs)
    rng = np.random.default_rng(seed)
    h = list(rng.uniform(0.0, 1.0, p))
    for _ in range(n):
        h.append(sum(a * x for a, x in zip(coeffs, h[-p:])) + c)
    return h


@pytest.mark.parametrize("coeffs,c", [
    ((0.4,), 2.0),
    ((0.3, -0.2), 5.0),
    ((0.25, 0.1, -0.3), 0.0),
])
def test_windowed_ar_recovers_ar_coefficients(coeffs, c):
    """Data generated by a stable AR(p) process is refit (least squares,
    noiseless) to the generating coefficients."""
    h = _ar_series(coeffs, c)
    p = len(coeffs)
    m = WindowedAR(order=p, window=200)
    for i, v in enumerate(h):
        m.observe(float(i), v)
    fit = m._fit()
    assert fit is not None
    assert np.allclose(fit[:p], coeffs, atol=1e-4)
    assert fit[p] == pytest.approx(c, abs=1e-4)
    # and the one-step forecast continues the process
    nxt = sum(a * x for a, x in zip(coeffs, h[-p:])) + c
    assert m.forecast(1.0) == pytest.approx(max(0.0, nxt), abs=1e-3)


def test_windowed_ar_long_horizons_never_blow_up():
    """Iterated AR forecasts with unstable fitted poles diverge
    geometrically; the rollout must clamp instead of returning inf/nan."""
    m = WindowedAR(order=4, window=64)
    for i in range(40):        # super-linear growth => explosive fit
        m.observe(float(i), float(i ** 3))
    for steps in (1, 50, 500):
        f = m.forecast(steps=steps)
        assert math.isfinite(f) and f >= 0.0


# ----------------------------------------------------------------- factory
def test_make_predictor_kinds_and_kwargs():
    assert isinstance(make_predictor("ewma"), EWMA)
    assert isinstance(make_predictor("holt", dt=2.0), HoltWinters)
    ar = make_predictor("ar", order=3, dt=4.0)
    assert isinstance(ar, WindowedAR)
    assert ar.order == 3 and ar.dt == 4.0
    with pytest.raises(KeyError):
        make_predictor("lstm")


# -------------------------------------------- property sweeps (hypothesis)
if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("mk", FORECASTERS)
    @settings(**SETTINGS)
    @given(st.lists(st.floats(0.0, 1e6), min_size=0, max_size=80),
           st.floats(0.0, 1e4))
    def test_prop_forecast_nonnegative_finite(mk, vals, horizon):
        p = mk()
        for i, v in enumerate(vals):
            p.observe(float(i), v)
        f = p.forecast(horizon)
        assert math.isfinite(f) and f >= 0.0

    @pytest.mark.parametrize("mk", FORECASTERS)
    @settings(**SETTINGS)
    @given(st.floats(0.0, 1e6), st.integers(1, 64), st.floats(0.0, 1e3))
    def test_prop_constant_history(mk, level, n, horizon):
        p = mk()
        for i in range(n):
            p.observe(float(i), level)
        assert p.forecast(horizon) == pytest.approx(level, rel=1e-6,
                                                    abs=1e-6)

    @settings(**SETTINGS)
    @given(st.floats(0.1, 50.0), st.floats(0.0, 100.0),
           st.floats(1.0, 200.0))
    def test_prop_holt_linear_ramp(slope, intercept, horizon):
        p = HoltWinters(dt=1.0)
        n = 400
        for i in range(n):
            p.observe(float(i), intercept + slope * i)
        want = intercept + slope * (n - 1) + slope * horizon
        assert p.forecast(horizon) == pytest.approx(want, rel=0.05, abs=1.0)

    @settings(**SETTINGS)
    @given(st.lists(st.floats(-0.4, 0.4), min_size=1, max_size=3),
           st.floats(0.0, 10.0))
    def test_prop_ar_coefficient_recovery(coeffs, c):
        h = _ar_series(list(coeffs), c)
        p = len(coeffs)
        m = WindowedAR(order=p, window=200)
        for i, v in enumerate(h):
            m.observe(float(i), v)
        fit = m._fit()
        assert fit is not None
        assert np.allclose(fit[:p], coeffs, atol=1e-4)

    @settings(**SETTINGS)
    @given(st.lists(st.floats(0.0, 1e3), min_size=6, max_size=64),
           st.integers(1, 500))
    def test_prop_ar_long_horizon_finite(vals, steps):
        m = WindowedAR(order=4, window=64)
        for i, v in enumerate(vals):
            m.observe(float(i), v)
        f = m.forecast(steps=steps)
        assert math.isfinite(f) and f >= 0.0
