"""Paged KV serving backend: token-exactness vs. the dense engine, prefix
caching (hits skip prefill, CoW on shared tails), per-block telemetry, and
the padded/true cost-model split."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import InferenceEngine, Request, SamplingParams

ARCH = "qwen2-0.5b-smoke"


def _mk(backend, **kw):
    cfg = get_config(ARCH)
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    kw.setdefault("seed", 0)
    return cfg, InferenceEngine(cfg, kv_backend=backend, **kw)


def _submit_all(eng, cfg, prompts, rid0=0, max_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=rid0 + i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=max_new)))


def test_paged_matches_dense_on_mixed_trace(rng):
    """Short (bucketed-on-dense), long (chunked), and mid prompts: greedy
    outputs are token-identical across backends, and the paged engine
    charges KV per block."""
    cfg = get_config(ARCH)
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, n)]
               for n in (5, 11, 20, 40, 7, 33)]
    outs = {}
    for backend in ("dense", "paged"):
        _, eng = _mk(backend)
        _submit_all(eng, cfg, prompts)
        done = eng.run(max_steps=300)
        assert len(done) == len(prompts)
        outs[backend] = {r.rid: r.output for r in done}
        if backend == "paged":
            assert eng.paged
            eng.prefix.check_invariants()
            peak = max(s.kv_blocks_used for s in eng.history)
            assert 0 < peak <= eng.num_blocks
            # per-block charge beats the dense per-row worst case: 6 rows
            # of short/mid prompts never touch rows*max_blk blocks
            assert peak < eng.capacity * eng.max_blk
            assert any(s.kv_util > 0 for s in eng.history)
    assert outs["dense"] == outs["paged"]


def test_prefix_cache_hits_skip_prefill(rng):
    """Re-serving the same prompts hits the prefix cache: fewer prompt
    tokens prefilled, hit telemetry reported, outputs unchanged."""
    cfg, eng = _mk("paged")
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, n)]
               for n in (9, 20, 40)]
    _submit_all(eng, cfg, prompts, max_new=4)
    first = {r.rid: r.output for r in eng.run(max_steps=300)}
    true1 = sum(s.prefill_tokens_true for s in eng.history)
    eng.history.clear()
    eng.finished.clear()
    _submit_all(eng, cfg, prompts, rid0=100, max_new=4)
    eng.run(max_steps=300)
    second = {r.rid: r.output for r in eng.finished}
    true2 = sum(s.prefill_tokens_true for s in eng.history)
    hits = sum(s.prefix_hit_tokens for s in eng.history)
    assert hits > 0
    assert true2 + hits == true1, "hits must replace prefill work 1:1"
    assert true2 < true1
    assert eng.history[-1].prefix_hit_rate > 0
    assert all(second[100 + i] == first[i] for i in range(len(prompts)))
    eng.prefix.check_invariants()


def test_shared_tail_cow_matches_dense(rng):
    """A continuation prompt (multi-turn) matches a partially-filled cached
    tail block; the engine must copy-on-write before appending, and the
    continuation must equal a cold dense serve of the same prompt."""
    cfg, eng = _mk("paged")
    p0 = [int(x) for x in rng.integers(0, cfg.vocab_size, 12)]
    eng.submit(Request(rid=0, prompt=list(p0),
                       sampling=SamplingParams(max_new_tokens=3)))
    turn1 = eng.run(max_steps=100)[0]
    cont = list(p0) + turn1.output[:2] + [int(rng.integers(0, cfg.vocab_size))]
    eng.finished.clear()
    eng.submit(Request(rid=1, prompt=list(cont),
                       sampling=SamplingParams(max_new_tokens=4)))
    got = eng.run(max_steps=100)[0]
    assert got.prefix_hit_tokens > 0
    assert got.prefix_hit_tokens % eng.block_size != 0, "tail block matched"
    assert eng.prefix.cow_copies >= 1
    _, ref_eng = _mk("dense")
    ref_eng.params = eng.params
    ref_eng.submit(Request(rid=1, prompt=list(cont),
                           sampling=SamplingParams(max_new_tokens=4)))
    assert ref_eng.run(max_steps=100)[0].output == got.output
    eng.prefix.check_invariants()


def test_padded_vs_true_token_accounting(rng):
    """Dense bucketed prefill reports both the compute launched (bucket
    round-up) and the prompt tokens it actually served."""
    cfg, eng = _mk("dense")
    eng.submit(Request(rid=0,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 5)],
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run(max_steps=40)
    st = [s for s in eng.history if s.prefill_tokens][0]
    assert st.prefill_tokens_true == 5
    assert st.prefill_tokens_padded == 8          # rounded to bucket 8
    assert st.prefill_tokens == st.prefill_tokens_true
    # admission cost exposes the same split
    req = Request(rid=1, prompt=list(range(5)), sampling=SamplingParams())
    assert eng._admit_cost(req) == (8, 5)


def test_tight_pool_drops_tail_hit_instead_of_deadlocking(rng):
    """A request whose worst-case footprint spans the whole pool and whose
    prompt matches a cached partial tail cannot afford the CoW slack block;
    the engine must drop the tail hit and serve, not requeue forever."""
    cfg, eng = _mk("paged", capacity=1, max_len=32)   # num_blocks == 4
    p0 = [int(x) for x in rng.integers(0, cfg.vocab_size, 12)]
    eng.submit(Request(rid=0, prompt=list(p0),
                       sampling=SamplingParams(max_new_tokens=3)))
    turn1 = eng.run(max_steps=60)[0]
    cont = list(p0) + turn1.output[:2] + [int(rng.integers(0, cfg.vocab_size))]
    eng.finished.clear()
    eng.submit(Request(rid=1, prompt=list(cont),
                       sampling=SamplingParams(max_new_tokens=20)))
    done = eng.run(max_steps=120)
    assert len(done) == 1 and done[0].state.name == "DONE"
    assert done[0].prefix_hit_tokens % eng.block_size == 0, \
        "tail hit should have been dropped under block pressure"
    eng.prefix.check_invariants()


def test_paged_backend_is_per_config(rng):
    """Families with per-row state keep the dense backend even when paged
    is requested — and still serve."""
    cfg = get_config("mamba2-780m-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=32, buckets=(8, 16),
                          kv_backend="paged", seed=0)
    assert not eng.paged
    eng.submit(Request(rid=0,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 6)],
                       sampling=SamplingParams(max_new_tokens=3)))
    assert len(eng.run(max_steps=60)) == 1


def test_paged_migration_is_guarded(rng):
    """Paged block-table handoff is an open edge: the migration layer skips
    paged replicas instead of corrupting them."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk("paged")
    _, eng_b = _mk("paged")
    eng_b.params = eng_a.params
    eng_a.submit(Request(rid=0,
                         prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 9)],
                         sampling=SamplingParams(max_new_tokens=8)))
    for _ in range(3):
        eng_a.step()
    assert MigrationManager().migrate(eng_a, eng_b, rid=0, now=0.0) is None
    with pytest.raises(NotImplementedError):
        eng_a.extract_row(0)
    assert len(eng_a.run(max_steps=60)) == 1      # request unharmed


def test_orchestrator_paged_prefix_affinity(rng):
    """Cluster layer over paged replicas: prefix-affinity routing sends a
    shared system prompt to one replica, whose cache then serves the hits;
    kv telemetry flows into the control-plane profiler."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    cfg = get_config(ARCH)

    def make_engine():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               kv_backend="paged", block_size=8, seed=11)

    orch = Orchestrator(make_engine, OrchestratorConfig(
        min_replicas=2, lb_policy="prefix",
        hpa=HPAConfig(metric="queue", target=100.0, max_replicas=2),
        control_every_steps=4))
    system = [int(x) for x in rng.integers(0, cfg.vocab_size, 24)]
    reqs = []
    for i in range(5):
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size, 4)]
        reqs.append(Request(rid=i, prompt=system + tail,
                            sampling=SamplingParams(max_new_tokens=3)))
        orch.submit(reqs[-1])
    done = orch.run(max_steps=400)
    assert len(done) == 5
    # affinity: every shared-prefix request landed on the same replica
    assert len({r.replica for r in done}) == 1
    hits = sum(s.prefix_hit_tokens
               for e in orch.engines for s in e.history)
    assert hits > 0
    assert any(orch.profiler.util[t].count() for t in orch.profiler.util
               if t.endswith("/kv"))
