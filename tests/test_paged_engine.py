"""Paged KV serving backend: token-exactness vs. the dense engine, prefix
caching (hits skip prefill, CoW on shared tails), per-block telemetry, and
the padded/true cost-model split."""
import numpy as np

from repro.configs import get_config
from repro.serving import InferenceEngine, Request, SamplingParams

ARCH = "qwen2-0.5b-smoke"


def _mk(backend, **kw):
    cfg = get_config(ARCH)
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    kw.setdefault("seed", 0)
    return cfg, InferenceEngine(cfg, kv_backend=backend, **kw)


def _submit_all(eng, cfg, prompts, rid0=0, max_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=rid0 + i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=max_new)))


def test_paged_matches_dense_on_mixed_trace(rng):
    """Short (bucketed-on-dense), long (chunked), and mid prompts: greedy
    outputs are token-identical across backends, and the paged engine
    charges KV per block."""
    cfg = get_config(ARCH)
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, n)]
               for n in (5, 11, 20, 40, 7, 33)]
    outs = {}
    for backend in ("dense", "paged"):
        _, eng = _mk(backend)
        _submit_all(eng, cfg, prompts)
        done = eng.run(max_steps=300)
        assert len(done) == len(prompts)
        outs[backend] = {r.rid: r.output for r in done}
        if backend == "paged":
            assert eng.paged
            eng.prefix.check_invariants()
            peak = max(s.kv_blocks_used for s in eng.history)
            assert 0 < peak <= eng.num_blocks
            # per-block charge beats the dense per-row worst case: 6 rows
            # of short/mid prompts never touch rows*max_blk blocks
            assert peak < eng.capacity * eng.max_blk
            assert any(s.kv_util > 0 for s in eng.history)
    assert outs["dense"] == outs["paged"]


def test_prefix_cache_hits_skip_prefill(rng):
    """Re-serving the same prompts hits the prefix cache: fewer prompt
    tokens prefilled, hit telemetry reported, outputs unchanged."""
    cfg, eng = _mk("paged")
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, n)]
               for n in (9, 20, 40)]
    _submit_all(eng, cfg, prompts, max_new=4)
    first = {r.rid: r.output for r in eng.run(max_steps=300)}
    true1 = sum(s.prefill_tokens_true for s in eng.history)
    eng.history.clear()
    eng.finished.clear()
    _submit_all(eng, cfg, prompts, rid0=100, max_new=4)
    eng.run(max_steps=300)
    second = {r.rid: r.output for r in eng.finished}
    true2 = sum(s.prefill_tokens_true for s in eng.history)
    hits = sum(s.prefix_hit_tokens for s in eng.history)
    assert hits > 0
    assert true2 + hits == true1, "hits must replace prefill work 1:1"
    assert true2 < true1
    assert eng.history[-1].prefix_hit_rate > 0
    assert all(second[100 + i] == first[i] for i in range(len(prompts)))
    eng.prefix.check_invariants()


def test_shared_tail_cow_matches_dense(rng):
    """A continuation prompt (multi-turn) matches a partially-filled cached
    tail block; the engine must copy-on-write before appending, and the
    continuation must equal a cold dense serve of the same prompt."""
    cfg, eng = _mk("paged")
    p0 = [int(x) for x in rng.integers(0, cfg.vocab_size, 12)]
    eng.submit(Request(rid=0, prompt=list(p0),
                       sampling=SamplingParams(max_new_tokens=3)))
    turn1 = eng.run(max_steps=100)[0]
    cont = list(p0) + turn1.output[:2] + [int(rng.integers(0, cfg.vocab_size))]
    eng.finished.clear()
    eng.submit(Request(rid=1, prompt=list(cont),
                       sampling=SamplingParams(max_new_tokens=4)))
    got = eng.run(max_steps=100)[0]
    assert got.prefix_hit_tokens > 0
    assert got.prefix_hit_tokens % eng.block_size != 0, "tail block matched"
    assert eng.prefix.cow_copies >= 1
    _, ref_eng = _mk("dense")
    ref_eng.params = eng.params
    ref_eng.submit(Request(rid=1, prompt=list(cont),
                           sampling=SamplingParams(max_new_tokens=4)))
    assert ref_eng.run(max_steps=100)[0].output == got.output
    eng.prefix.check_invariants()


def test_padded_vs_true_token_accounting(rng):
    """Dense bucketed prefill reports both the compute launched (bucket
    round-up) and the prompt tokens it actually served."""
    cfg, eng = _mk("dense")
    eng.submit(Request(rid=0,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 5)],
                       sampling=SamplingParams(max_new_tokens=2)))
    eng.run(max_steps=40)
    st = [s for s in eng.history if s.prefill_tokens][0]
    assert st.prefill_tokens_true == 5
    assert st.prefill_tokens_padded == 8          # rounded to bucket 8
    assert st.prefill_tokens == st.prefill_tokens_true
    # admission cost exposes the same split
    req = Request(rid=1, prompt=list(range(5)), sampling=SamplingParams())
    assert eng._admit_cost(req) == (8, 5)


def test_tight_pool_drops_tail_hit_instead_of_deadlocking(rng):
    """A request whose worst-case footprint spans the whole pool and whose
    prompt matches a cached partial tail cannot afford the CoW slack block;
    the engine must drop the tail hit and serve, not requeue forever."""
    cfg, eng = _mk("paged", capacity=1, max_len=32)   # num_blocks == 4
    p0 = [int(x) for x in rng.integers(0, cfg.vocab_size, 12)]
    eng.submit(Request(rid=0, prompt=list(p0),
                       sampling=SamplingParams(max_new_tokens=3)))
    turn1 = eng.run(max_steps=60)[0]
    cont = list(p0) + turn1.output[:2] + [int(rng.integers(0, cfg.vocab_size))]
    eng.finished.clear()
    eng.submit(Request(rid=1, prompt=list(cont),
                       sampling=SamplingParams(max_new_tokens=20)))
    done = eng.run(max_steps=120)
    assert len(done) == 1 and done[0].state.name == "DONE"
    assert done[0].prefix_hit_tokens % eng.block_size == 0, \
        "tail hit should have been dropped under block pressure"
    eng.prefix.check_invariants()


def test_paged_backend_is_per_config(rng):
    """Families with per-row state keep the dense backend even when paged
    is requested — and still serve."""
    cfg = get_config("mamba2-780m-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=32, buckets=(8, 16),
                          kv_backend="paged", seed=0)
    assert not eng.paged
    eng.submit(Request(rid=0,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 6)],
                       sampling=SamplingParams(max_new_tokens=3)))
    assert len(eng.run(max_steps=60)) == 1


def test_paged_migration_preserves_generation(rng):
    """Block-table handoff between paged replicas: a request migrated
    mid-decode produces bit-identical greedy output to an unmigrated run,
    and both engines' block spaces stay invariant-clean."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk("paged")
    _, eng_b = _mk("paged")
    eng_b.params = eng_a.params
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 20)]
    ref_eng = _mk("paged")[1]
    ref_eng.params = eng_a.params
    ref_eng.submit(Request(rid=0, prompt=list(prompt),
                           sampling=SamplingParams(max_new_tokens=8)))
    ref = ref_eng.run(max_steps=100)[0].output

    req = Request(rid=0, prompt=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng_a.submit(req)
    for _ in range(5):                 # chunked prefill + a few decode steps
        eng_a.step()
    assert req.state.name == "DECODE" and len(req.output) >= 2
    mgr = MigrationManager()
    ev = mgr.migrate(eng_a, eng_b, rid=0, now=0.0)
    assert ev is not None and ev.bytes > 0 and ev.phase == "decode"
    done = eng_b.run(max_steps=100)
    assert done[0].output == ref
    assert done[0].migrations == 1
    eng_a.prefix.check_invariants()
    eng_b.prefix.check_invariants()


def test_paged_migration_skips_destination_cached_blocks(rng):
    """Cross-replica prefix handoff: migrating a request whose prompt the
    destination already caches transfers fewer bytes than its full
    kv_bytes, and the transferred blocks are donated into the destination
    index so a subsequent identical prompt hits them."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk("paged")
    _, eng_b = _mk("paged")
    eng_b.params = eng_a.params
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 20)]
    # warm the destination's prefix cache with the same prompt
    eng_b.submit(Request(rid=9, prompt=list(prompt),
                         sampling=SamplingParams(max_new_tokens=2)))
    ref = eng_b.run(max_steps=60)[0]
    eng_b.finished.clear()

    req = Request(rid=0, prompt=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng_a.submit(req)
    for _ in range(4):
        eng_a.step()
    full = eng_a.kv_bytes(0)
    ev = MigrationManager().migrate(eng_a, eng_b, rid=0, now=0.0)
    assert ev is not None
    assert ev.blocks_skipped > 0
    assert ev.bytes < full == ev.bytes_full, "dst-cached blocks still shipped"
    done = eng_b.run(max_steps=100)[0]
    assert done.output[:2] == ref.output    # same greedy continuation
    # donation: a fresh identical prompt now hits the migrated blocks too
    eng_b.finished.clear()
    eng_b.submit(Request(rid=1, prompt=list(prompt),
                         sampling=SamplingParams(max_new_tokens=2)))
    got = eng_b.run(max_steps=60)[0]
    assert got.prefix_hit_tokens > 0
    eng_b.prefix.check_invariants()


def test_paged_migration_rollback_and_requeue(rng, monkeypatch):
    """A refused handoff rolls back into the source; if the source cannot
    re-admit either, the request is explicitly requeued at the source
    scheduler (never silently dropped) and the failure is recorded."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk("paged", capacity=1)
    _, eng_b = _mk("paged", capacity=1)
    eng_b.params = eng_a.params
    pa = [int(x) for x in rng.integers(0, cfg.vocab_size, 10)]
    eng_a.submit(Request(rid=0, prompt=list(pa),
                         sampling=SamplingParams(max_new_tokens=8)))
    eng_b.submit(Request(rid=1,
                         prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 10)],
                         sampling=SamplingParams(max_new_tokens=8)))
    for _ in range(3):
        eng_a.step()
        eng_b.step()
    ref_eng = _mk("paged", capacity=1)[1]
    ref_eng.params = eng_a.params
    ref_eng.submit(Request(rid=5, prompt=list(pa),
                           sampling=SamplingParams(max_new_tokens=8)))
    ref = ref_eng.run(max_steps=100)[0].output

    # destination full -> rollback into the source, generation unharmed
    mgr = MigrationManager()
    assert mgr.migrate(eng_a, eng_b, rid=0, now=0.0) is None
    assert mgr.failures[-1].reason == "dst-full"
    assert eng_a.run(max_steps=100)[0].output == ref
    eng_a.finished.clear()

    # source cannot re-admit either -> explicit requeue, still served.
    # eng_b is drained first so the cheap probe passes and the handoff
    # reaches the adopt stage, where both engines are forced to refuse.
    eng_b.run(max_steps=100)
    eng_a.submit(Request(rid=0, prompt=list(pa),
                         sampling=SamplingParams(max_new_tokens=8)))
    for _ in range(3):
        eng_a.step()
    real_adopt = eng_a.adopt
    monkeypatch.setattr(eng_a, "adopt",
                        lambda req, payload, now=None: False)
    monkeypatch.setattr(eng_b, "adopt",
                        lambda req, payload, now=None: False)
    assert mgr.migrate(eng_a, eng_b, rid=0, now=0.0) is None
    assert mgr.failures[-1].reason == "requeued"
    assert eng_a.scheduler.depth() == 1       # back in the source queue
    monkeypatch.setattr(eng_a, "adopt", real_adopt)
    done = eng_a.run(max_steps=100)
    assert len(done) == 1 and done[0].output == ref
    eng_a.prefix.check_invariants()


def test_paged_disaggregation_hands_off_every_request(rng):
    """A paged DisaggregatedServer moves every request to the decode pool:
    multi-chunk prompts at their last chunk boundary (zero decode tokens on
    prefill engines), outputs identical to a monolithic paged serve, and
    handoff telemetry exposed per step."""
    from repro.core.disaggregation import DisaggConfig, DisaggregatedServer
    cfg = get_config(ARCH)

    def mk():
        return InferenceEngine(cfg, capacity=4, max_len=96, buckets=(8, 16),
                               kv_backend="paged", block_size=8, seed=21)

    rng_p = np.random.default_rng(3)
    prompts = [[int(x) for x in rng_p.integers(0, cfg.vocab_size, n)]
               for n in (40, 25, 33, 29)]           # all multi-chunk
    ref_eng = mk()
    for i, p in enumerate(prompts):
        ref_eng.submit(Request(rid=i, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=6)))
    ref = {r.rid: r.output for r in ref_eng.run(max_steps=300)}

    srv = DisaggregatedServer(mk, DisaggConfig(prefill_engines=1,
                                               decode_engines=2))
    srv.prefill_pool[0].params = ref_eng.params
    for e in srv.decode_pool:
        e.params = ref_eng.params
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=6)))
    done = srv.run(max_steps=400)
    assert {r.rid: r.output for r in done} == ref
    assert all(r.migrations == 1 for r in done)
    assert all(e.phase == "prefill" for e in srv.migrations.events), \
        "multi-chunk prompts should hand off at a chunk boundary"
    # prefill engines never ran a decode step after (or before) handoff
    assert sum(s.tokens_out for pe in srv.prefill_pool
               for s in pe.history) == 0
    assert sum(s.handoffs_succeeded for s in srv.history) == len(prompts)
    assert sum(s.handoffs_failed for s in srv.history) == 0
    for e in srv.prefill_pool + srv.decode_pool:
        e.prefix.check_invariants()


def test_orchestrator_paged_prefix_affinity(rng):
    """Cluster layer over paged replicas: prefix-affinity routing sends a
    shared system prompt to one replica, whose cache then serves the hits;
    kv telemetry flows into the control-plane profiler."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    cfg = get_config(ARCH)

    def make_engine():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               kv_backend="paged", block_size=8, seed=11)

    orch = Orchestrator(make_engine, OrchestratorConfig(
        min_replicas=2, lb_policy="prefix",
        hpa=HPAConfig(metric="queue", target=100.0, max_replicas=2),
        control_every_steps=4))
    system = [int(x) for x in rng.integers(0, cfg.vocab_size, 24)]
    reqs = []
    for i in range(5):
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size, 4)]
        reqs.append(Request(rid=i, prompt=system + tail,
                            sampling=SamplingParams(max_new_tokens=3)))
        orch.submit(reqs[-1])
    done = orch.run(max_steps=400)
    assert len(done) == 5
    # affinity: every shared-prefix request landed on the same replica
    assert len({r.replica for r in done}) == 1
    hits = sum(s.prefix_hit_tokens
               for e in orch.engines for s in e.history)
    assert hits > 0
    assert any(orch.profiler.util[t].count() for t in orch.profiler.util
               if t.endswith("/kv"))
