"""The simulated cluster transport and both planes refactored onto it:
delivery semantics (latency, FIFO, bandwidth sharing, bounded queues,
fault classes, partitions), the cache-directory bridge's loss/reorder
tolerance (hypothesis-guarded conservative-subset property), async
block-granular migration's token identity with the synchronous path,
cross-backend payload conversion, dst-full retry backoff, and per-link
migration planning."""
import pytest

from repro.configs import get_config
from repro.core.cache_directory import ClusterCacheDirectory
from repro.core.disaggregation import DisaggConfig, DisaggregatedServer
from repro.core.migration import MigrationConfig, MigrationManager
from repro.core.transport import (DirectoryTransportClient,
                                  DirectoryTransportService, FaultSpec,
                                  LinkSpec, Transport)
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request, SamplingParams

ARCH = "qwen2-0.5b-smoke"


def _mk(backend="paged", **kw):
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("block_size", 8)
    kw.setdefault("seed", 0)
    return InferenceEngine(get_config(ARCH), kv_backend=backend, **kw)


def _req(rid, prompt=None, max_new=10):
    return Request(rid=rid, prompt=prompt or list(range(1, 13)),
                   sampling=SamplingParams(max_new_tokens=max_new))


# ---------------------------------------------------------------- fabric
def test_latency_and_fifo_order():
    tp = Transport(LinkSpec(latency_steps=3, bandwidth=float("inf")))
    got = []
    tp.register("b", "x", lambda m, now: got.append((now, m.payload)))
    tp.send("a", "b", "x", 1)
    tp.send("a", "b", "x", 2)
    tp.step(2)
    assert got == [], "nothing delivers before the link latency elapses"
    tp.step()
    assert got == [(3, 1), (3, 2)], "FIFO at the latency boundary"


def test_bandwidth_serialization_and_fair_share():
    # one 250-byte message on a 100 B/step link: ceil(250/100)=3 steps
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=100))
    got = []
    tp.register("b", "x", lambda m, now: got.append(now))
    tp.send("a", "b", "x", 0, size_bytes=250)
    tp.step(5)
    assert got == [3]
    # two 100-byte messages sent together share the link: 50 B/step each,
    # both fully serialized (and delivered, FIFO) at step 2 — a lone one
    # would take 1 step.  Contention is modeled, not assumed away.
    tp2 = Transport(LinkSpec(latency_steps=1, bandwidth=100))
    got2 = []
    tp2.register("b", "x", lambda m, now: got2.append(now))
    tp2.send("a", "b", "x", 0, size_bytes=100)
    tp2.send("a", "b", "x", 1, size_bytes=100)
    tp2.step(5)
    assert got2 == [2, 2]


def test_bounded_queue_backpressure():
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf"),
                            max_in_flight=2))
    assert tp.send("a", "b", "x", 0)
    assert tp.send("a", "b", "x", 1)
    assert not tp.send("a", "b", "x", 2), "full queue must refuse the send"
    assert tp.counts["rejected"] == 1
    tp.step()
    assert tp.send("a", "b", "x", 2), "drained queue accepts again"


def test_faults_spare_the_reliable_class():
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf")),
                   FaultSpec(drop=1.0, seed=0))
    got = []
    tp.register("b", "x", lambda m, now: got.append(m.payload))
    for i in range(4):
        tp.send("a", "b", "x", ("rel", i), reliable=True)
        tp.send("a", "b", "x", ("unrel", i), reliable=False)
    tp.step(3)
    assert got == [("rel", i) for i in range(4)], \
        "drop=1.0 eats every unreliable message and no reliable one"
    assert tp.counts["dropped"] == 4


def test_duplicate_fault_delivers_twice():
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf")),
                   FaultSpec(duplicate=1.0, seed=0))
    got = []
    tp.register("b", "x", lambda m, now: got.append(m.payload))
    tp.send("a", "b", "x", 7, reliable=False)
    tp.step(2)
    assert got == [7, 7]


def test_partition_stalls_without_loss():
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf")))
    got = []
    tp.register("b", "x", lambda m, now: got.append(m.payload))
    tp.send("a", "b", "x", 1, reliable=True)
    tp.partition("a", "b")
    tp.step(5)
    assert got == [] and tp.in_flight() == 1, "partitioned traffic waits"
    tp.heal("a", "b")
    tp.step()
    assert got == [1], "healing releases everything queued"


# ------------------------------------------------- directory over the wire
def _truth_equal(directory, truth):
    for r, chains in truth.items():
        assert directory.claimed(r) == chains, \
            (r, directory.claimed(r) ^ chains)


def test_directory_bridge_anti_entropy_repairs_loss():
    """Deterministic loss schedule: dropped deltas leave the directory
    stale (subset semantics keep routing safe); the next reconcile
    snapshot restores exact agreement."""
    directory = ClusterCacheDirectory()
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf")),
                   FaultSpec(drop=1.0, seed=0))
    DirectoryTransportService(directory).bind(tp, "ctrl")
    client = DirectoryTransportClient(tp, "r0", "ctrl")
    for c in (11, 22, 33):
        client.on_insert(0, c)
    tp.step(3)
    assert directory.claimed(0) == set(), "every delta was dropped"
    tp.faults = FaultSpec()              # network heals
    client.reconcile(0, {11, 22, 33})
    tp.quiesce()
    _truth_equal(directory, {0: {11, 22, 33}})


def test_directory_service_ignores_pre_reconcile_stragglers():
    """A delta generated before a reconcile snapshot but delivered after
    it must not resurrect state the snapshot superseded."""
    directory = ClusterCacheDirectory()
    service = DirectoryTransportService(directory)
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf")))
    service.bind(tp, "ctrl")
    client = DirectoryTransportClient(tp, "r0", "ctrl")
    client.on_insert(0, 11)              # seq 1 — held back below
    client.on_evict(0, 11)               # seq 2 (lost in this scenario)
    client.reconcile(0, set())           # seq 3: replica truly holds nothing
    # simulate delivery out of order: reconcile first, then the old insert
    msgs = sorted(tp._queues[("r0", "ctrl")], key=lambda m: -m.seq)
    for m in msgs:
        if m.payload["op"] != "evict":   # the evict delta never arrives
            service.handle(m, 0)
    assert directory.claimed(0) == set(), \
        "the stale insert must not reappear behind the reconcile"
    assert service.stale_ignored >= 1


# ----------------------------------------- async migration token identity
def _ref_output():
    e = _mk()
    e.submit(_req(0))
    while e.pending():
        e.step(0.0)
    return list(e.finished[0].output)


def _migrated_output(async_path, warm_steps=4):
    a, b = _mk(), _mk()
    b.params = a.params
    a.submit(_req(0))
    for _ in range(warm_steps):
        a.step(0.0)
    mgr = MigrationManager(MigrationConfig())
    if not async_path:
        assert mgr.migrate(a, b, 0, 0.0) is not None
    else:
        # one block per step on the wire: the transfer spans several steps
        # while BOTH engines keep stepping — overlap, not stop-and-copy
        tp = Transport(LinkSpec(latency_steps=1,
                                bandwidth=b.kv_per_block_bytes()))
        assert mgr.migrate_async(a, b, 0, 0.0, tp, "A", "B")
        t = 0.0
        while mgr.transfers_in_flight:
            mgr.pump(t, tp)
            tp.step()
            a.step(t)
            b.step(t)
            t += 1.0
        assert mgr.events and mgr.events[-1].chunks > 1, \
            "the transfer must actually have been chunked"
    while b.pending():
        b.step(1.0)
    return list(b.finished[0].output)


def test_async_adoption_token_identical_to_sync():
    """The acceptance bar: with fault injection off, the block-granular
    async path and the synchronous whole-payload path produce the same
    token stream (greedy sampling; both equal the unmigrated run)."""
    ref = _ref_output()
    assert _migrated_output(async_path=False) == ref
    assert _migrated_output(async_path=True) == ref


def test_async_adoption_mid_prefill_token_identical():
    """Chunk-boundary mid-prefill handoff over the transport: the pending
    row resumes its remaining prompt on the destination, token-identical."""
    long_prompt = list(range(1, 25))     # chunked on (8, 16) buckets
    e = _mk()
    e.submit(_req(0, long_prompt))
    while e.pending():
        e.step(0.0)
    ref = list(e.finished[0].output)

    a, b = _mk(), _mk()
    b.params = a.params
    a.submit(_req(0, long_prompt))
    a.step(0.0)                          # first chunk consumed
    mgr = MigrationManager(MigrationConfig())
    tp = Transport(LinkSpec(latency_steps=1,
                            bandwidth=b.kv_per_block_bytes()))
    assert mgr.migrate_async(a, b, 0, 0.0, tp, "A", "B")
    assert mgr.events == [] or mgr.events[-1].phase == "prefill"
    t = 0.0
    while mgr.transfers_in_flight:
        mgr.pump(t, tp)
        tp.step()
        a.step(t)
        b.step(t)
        t += 1.0
    assert mgr.events[-1].phase == "prefill"
    while b.pending():
        b.step(1.0)
    assert list(b.finished[0].output) == ref


def test_disaggregated_handoff_over_transport_token_identical():
    def run(transport):
        srv = DisaggregatedServer(
            lambda: _mk(),
            DisaggConfig(prefill_engines=1, decode_engines=2,
                         transport=transport))
        for i in range(4):
            srv.submit(_req(i, [1, 2, 3, 4, 5, 6, 7, 8, 10 + i, 20 + i],
                            max_new=8), now=0.0)
        done = srv.run(2000)
        assert len(done) == 4
        return {r.rid: list(r.output) for r in done}

    base = run(None)
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=2048,
                            max_in_flight=8))
    assert run(tp) == base


# -------------------------------------------- cross-backend conversion
@pytest.mark.parametrize("src_backend,dst_backend",
                         [("dense", "paged"), ("paged", "dense")])
def test_cross_backend_migration_converts_payload(src_backend, dst_backend):
    ref = _ref_output()                  # backends are token-identical
    a, b = _mk(src_backend), _mk(dst_backend)
    b.params = a.params
    a.submit(_req(0))
    for _ in range(4):
        a.step(0.0)
    mgr = MigrationManager(MigrationConfig())
    ev = mgr.migrate(a, b, 0, 0.0)
    assert ev is not None, mgr.failures
    assert not any(f.reason == "backend-mismatch" for f in mgr.failures)
    while b.pending():
        b.step(0.0)
    assert list(b.finished[0].output) == ref
    if dst_backend == "paged":
        b.prefix.check_invariants()


def test_backend_mismatch_kept_for_unservable_shapes(monkeypatch):
    """The failure reason survives exactly for payloads with no block
    representation (can_convert False — e.g. SSM per-row state)."""
    a, b = _mk("dense"), _mk("paged")
    b.params = a.params
    a.submit(_req(0))
    for _ in range(4):
        a.step(0.0)
    monkeypatch.setattr(b, "can_convert", lambda other: False)
    mgr = MigrationManager(MigrationConfig())
    assert mgr.migrate(a, b, 0, 0.0) is None
    assert mgr.failures[-1].reason == "backend-mismatch"
    # the source still serves the request — nothing was extracted
    while a.pending():
        a.step(0.0)
    assert len(a.finished) == 1


# ------------------------------------------------------- retry backoff
def test_dst_full_retry_backoff_caps_and_clears():
    cfg = MigrationConfig(retry_base_steps=2.0, retry_backoff=2.0,
                          retry_cap_steps=8.0, retry_max_attempts=4)
    mgr = MigrationManager(cfg)
    a = _mk()
    b = _mk(capacity=1, num_blocks=8)    # one row, tiny pool: refuses adopts
    b.params = a.params
    b.submit(_req(7, [1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=30))
    for _ in range(2):
        b.step(0.0)
    a.submit(_req(0))
    for _ in range(4):
        a.step(0.0)
    assert mgr.migrate(a, b, 0, 0.0) is None
    assert mgr.failures[-1].reason == "dst-full"
    st = mgr.retry_state(0)
    assert st["attempts"] == 1 and st["next_try"] == pytest.approx(2.0)
    assert mgr.ready_to_retry(1.0) == [], "backoff not yet elapsed"
    assert mgr.ready_to_retry(2.0) == [0]
    # repeated refusals double the delay up to the cap...
    assert mgr.migrate(a, b, 0, 2.0) is None
    assert mgr.retry_state(0)["next_try"] == pytest.approx(2.0 + 4.0)
    assert mgr.migrate(a, b, 0, 6.0) is None
    assert mgr.retry_state(0)["next_try"] == pytest.approx(6.0 + 8.0)
    assert mgr.migrate(a, b, 0, 14.0) is None
    assert mgr.retry_state(0)["next_try"] == pytest.approx(14.0 + 8.0), \
        "delay is capped at retry_cap_steps"
    # ...and past max_attempts the move is abandoned
    assert mgr.ready_to_retry(1e9) == []
    # success on a roomy destination clears the backoff state
    c = _mk()
    c.params = a.params
    assert mgr.migrate(a, c, 0, 22.0) is not None
    assert mgr.retry_state(0) is None


# ------------------------------------------- per-link planning/contention
def test_max_concurrent_enforced_per_link():
    """``max_concurrent`` caps in-flight transfers *per link*, not
    globally: a saturated link refuses the next transfer (backpressure —
    retry next tick, no failure recorded) while a different link to a
    third replica accepts it the same tick."""
    a, b, c = _mk(), _mk(), _mk()
    b.params = a.params
    c.params = a.params
    a.submit(_req(0))
    a.submit(_req(1))
    for _ in range(4):
        a.step(0.0)
    mgr = MigrationManager(MigrationConfig(max_concurrent=1))
    # one block per 4 steps: transfer 0 is still in flight at the refusal
    tp = Transport(LinkSpec(latency_steps=1,
                            bandwidth=a.kv_per_block_bytes() / 4))
    assert mgr.migrate_async(a, b, 0, 0.0, tp, "na", "nb", 0, 1)
    f0 = mgr.failed
    assert not mgr.migrate_async(a, b, 1, 0.0, tp, "na", "nb", 0, 1), \
        "saturated link accepted a second transfer"
    assert mgr.failed == f0, "a saturated link is backpressure, not failure"
    assert mgr.migrate_async(a, c, 1, 0.0, tp, "na", "nc", 0, 2)
    assert mgr.transfers_in_flight == 2
    # the planner respects the same budget: one move per tick here
    assert len(mgr.plan([1.0, 0.95, 0.0, 0.05])) == 1
    for _ in range(200):
        if not mgr.transfers_in_flight:
            break
        mgr.pump(0.0, tp)
        tp.step()
    assert mgr.succeeded == 2
    done = b.run(max_steps=300) + c.run(max_steps=300)
    assert {r.rid for r in done} == {0, 1}


def test_sync_contention_stretches_duration():
    mgr = MigrationManager(MigrationConfig())
    t1 = mgr.transfer_time(1_000_000)
    t2 = mgr.transfer_time(1_000_000, concurrent=2)
    assert t2 - mgr.cfg.overhead_s == pytest.approx(
        2 * (t1 - mgr.cfg.overhead_s))


def test_async_link_contention_measured_in_duration():
    """Two transfers sharing one link each see half the bandwidth: their
    measured duration_s roughly doubles a lone transfer's."""
    def drain(n_reqs):
        a, b = _mk(), _mk()
        b.params = a.params
        for i in range(n_reqs):
            a.submit(_req(i, list(range(1, 13)), max_new=20))
        for _ in range(4):
            a.step(0.0)
        mgr = MigrationManager(MigrationConfig(max_concurrent=2))
        tp = Transport(LinkSpec(latency_steps=1,
                                bandwidth=b.kv_per_block_bytes()))
        for i in range(n_reqs):
            assert mgr.migrate_async(a, b, i, 0.0, tp, "A", "B")
        t = 0.0
        while mgr.transfers_in_flight:
            mgr.pump(t, tp)
            tp.step()
            t += 1.0
            assert t < 500
        return max(e.duration_s for e in mgr.events)

    lone, shared = drain(1), drain(2)
    assert shared >= 2 * lone - 1, (lone, shared)
