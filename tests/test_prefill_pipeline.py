"""Prefill-pipeline regression tests.

Covers the batched + chunked prefill subsystem: long prompts (beyond the
largest bucket) served via chunked prefill and matching the unchunked
reference; batched multi-request prefill equivalent to sequential admission;
arrival=0.0 scheduler semantics; kv_bytes proportional to sequence length.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.lm import make_model
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.request import State
from repro.serving.scheduler import Scheduler, SchedulerConfig


def _greedy_reference(eng, prompt, n_new):
    """Unchunked reference: one full-length prefill + straight-line decode,
    with the engine's own params/max_len."""
    model = make_model(eng.cfg)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, eng.max_len))(
        eng.params, {"tokens": toks})
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    cur = jnp.asarray([[out[-1]]], jnp.int32)
    step = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        logits, cache = step(eng.params, cur, pos, cache)
        out.append(int(jnp.argmax(logits, -1)[0]))
        cur = jnp.asarray([[out[-1]]], jnp.int32)
        pos = pos + 1
    return out


# ----------------------------------------------------------- chunked prefill
@pytest.mark.parametrize("arch", [
    "qwen2-0.5b-smoke", "mamba2-780m-smoke",
    pytest.param("gemma3-27b-smoke", marks=pytest.mark.slow),
])
def test_long_prompt_served_via_chunks_matches_reference(arch, rng):
    """A prompt longer than the largest bucket completes (no ValueError) and
    the greedy output equals the unchunked full-prefill reference.
    Covers global attention, SSM state carry, and ring (local) layers."""
    cfg = get_config(arch)
    eng = InferenceEngine(cfg, capacity=2, max_len=96, buckets=(8, 16), seed=7)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 39)]
    eng.submit(Request(rid=0, prompt=list(prompt),
                       sampling=SamplingParams(max_new_tokens=6)))
    done = eng.run(max_steps=80)
    assert len(done) == 1 and done[0].state is State.DONE
    assert done[0].output == _greedy_reference(eng, prompt, 6)
    # the prompt was consumed in bounded chunks, not one oversized prefill
    assert sum(s.chunk_rows for s in eng.history) >= 3


def test_chunked_and_bucketed_paths_agree(rng):
    """The same prompt served through a large bucket vs through chunked
    prefill (buckets smaller than the prompt) gives identical greedy output."""
    cfg = get_config("qwen2-0.5b-smoke")
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 13)]
    outs = []
    for buckets in [(16,), (8,)]:   # 13 <= 16 bucketed; 13 > 8 chunked
        eng = InferenceEngine(cfg, capacity=2, max_len=64, buckets=buckets,
                              seed=3)
        eng.submit(Request(rid=0, prompt=list(prompt),
                           sampling=SamplingParams(max_new_tokens=5)))
        outs.append(eng.run(max_steps=40)[0].output)
    assert outs[0] == outs[1], outs


def test_long_prompt_interleaves_with_decodes(rng):
    """Running decodes keep producing tokens while a long prompt chunks
    through prefill under a per-step token budget."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(
        cfg, capacity=4, max_len=96, buckets=(8, 16), seed=11,
        sched=SchedulerConfig(max_prefill_per_step=4, prefill_token_budget=16))
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 6)],
            sampling=SamplingParams(max_new_tokens=10)))
    eng.submit(Request(rid=3,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 60)],
                       sampling=SamplingParams(max_new_tokens=4)))
    # short prompts admitted first step; the 60-token prompt needs >= 4
    # budgeted chunk steps, during which the shorts must still decode
    decode_during_chunk = 0
    for _ in range(200):
        st = eng.step()
        if st.chunk_rows and st.tokens_out:
            decode_during_chunk += 1
        if not eng.pending():
            break
    done = {r.rid: r for r in eng.finished}
    assert len(done) == 4
    assert len(done[3].output) == 4
    assert decode_during_chunk >= 2, "chunked prefill blocked all decodes"
    # budget bounds per-step prefill work (one 16-token chunk at a time
    # once the pool is busy)
    assert max(s.prefill_tokens for s in eng.history) <= 16 + 3 * 8


def test_prefill_token_accounting(rng):
    """StepStats.prefill_tokens sums to the served prompt tokens."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(cfg, capacity=4, max_len=96, buckets=(8, 16), seed=2)
    lens = [5, 12, 40, 7]
    for i, n in enumerate(lens):
        eng.submit(Request(rid=i,
                           prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, n)],
                           sampling=SamplingParams(max_new_tokens=3)))
    eng.run(max_steps=100)
    assert sum(s.prefill_tokens for s in eng.history) == sum(lens)


def test_oversized_prompt_rejected_not_crashed(rng):
    """Prompts that cannot fit a cache row bounce as REJECTED."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=32, buckets=(8, 16), seed=1)
    req = Request(rid=0, prompt=[1] * 40)        # > max_len - 1
    assert not eng.submit(req)
    assert req.state is State.REJECTED and eng.rejected_long == 1
    # vision-prefix families cannot chunk: longer-than-bucket bounces too
    vcfg = get_config("paligemma-3b-smoke")
    veng = InferenceEngine(vcfg, capacity=2, max_len=48, buckets=(8,), seed=1)
    vreq = Request(rid=0, prompt=[1] * 20)
    assert not veng.submit(vreq)
    assert vreq.state is State.REJECTED


# ----------------------------------------------------------- batched prefill
def test_batched_prefill_matches_sequential_admission(rng):
    """max_prefill_per_step=4 (one batched call per bucket) produces the
    same greedy outputs as one-request-per-step admission."""
    cfg = get_config("qwen2-0.5b-smoke")
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size,
                                             int(rng.integers(3, 15)))]
               for _ in range(6)]
    outs = []
    for mpps in (4, 1):
        eng = InferenceEngine(cfg, capacity=8, max_len=64, buckets=(8, 16),
                              seed=17, sched=SchedulerConfig(
                                  max_prefill_per_step=mpps))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=5)))
        done = eng.run(max_steps=60)
        assert len(done) == 6
        outs.append({r.rid: r.output for r in done})
    assert outs[0] == outs[1]
    # batched engine actually admitted multiple requests in one step
    # (can't be checked on outs — check the stats history)


def test_batched_prefill_admits_multiple_per_step(rng):
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(cfg, capacity=8, max_len=64, buckets=(16,), seed=5)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 9)],
                           sampling=SamplingParams(max_new_tokens=3)))
    st = eng.step()
    assert st.n_prefill == 4, "admission should batch up to max_prefill_per_step"
    # each admitted request has its prefill first token + one decode token
    assert all(len(r.output) == 2 for r in eng.row_req.values())


def test_max_new_tokens_one_yields_exactly_one(rng):
    """A request satisfied by its prefill first token must not pick up a
    same-step decode token (both bucketed and chunked paths)."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=96, buckets=(8, 16), seed=4)
    eng.submit(Request(rid=0, prompt=[int(x) for x in rng.integers(0, 64, 6)],
                       sampling=SamplingParams(max_new_tokens=1)))
    eng.submit(Request(rid=1, prompt=[int(x) for x in rng.integers(0, 64, 40)],
                       sampling=SamplingParams(max_new_tokens=1)))
    done = eng.run(max_steps=60)
    assert sorted((r.rid, len(r.output)) for r in done) == [(0, 1), (1, 1)]


# ----------------------------------------------------------------- scheduler
def test_arrival_zero_is_preserved():
    """An explicit arrival == 0.0 must not be overwritten at submit (sjf/slo
    ordering and timeout expiry in simulations that start at t=0)."""
    s = Scheduler(SchedulerConfig(policy="fcfs", max_prefill_per_step=2))
    early = Request(rid=0, prompt=[1] * 4, arrival=0.0)
    late = Request(rid=1, prompt=[1] * 4, arrival=5.0)
    s.submit(late, now=5.0)
    s.submit(early, now=6.0)     # submitted later, but arrived at t=0
    assert early.arrival == 0.0
    picked = s.next_batch(2, now=7.0)
    assert [r.rid for r in picked] == [0, 1]


def test_arrival_zero_timeout_expires():
    s = Scheduler(SchedulerConfig(admission_timeout=5.0))
    s.submit(Request(rid=0, prompt=[1] * 4, arrival=0.0), now=0.0)
    assert s.next_batch(1, now=10.0) == []
    assert s.rejected == 1


def test_unstamped_arrival_gets_submit_time():
    s = Scheduler(SchedulerConfig())
    r = Request(rid=0, prompt=[1] * 4)
    s.submit(r, now=3.5)
    assert r.arrival == 3.5


def test_token_budget_bounds_admission():
    s = Scheduler(SchedulerConfig(max_prefill_per_step=8))
    for i in range(4):
        s.submit(Request(rid=i, prompt=[1] * 10), now=float(i))
    picked = s.next_batch(8, now=9.0, budget=25)
    assert len(picked) == 2              # 10 + 10 fit, third would exceed
    # first pick always goes through even when it alone exceeds the budget
    picked = s.next_batch(8, now=9.0, budget=3)
    assert len(picked) == 1


# ------------------------------------------------------------------ kv_bytes
def test_kv_bytes_scales_with_sequence_length(rng):
    cfg = get_config("qwen2-0.5b-smoke")
    eng = InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 32), seed=9)
    eng.submit(Request(rid=0, prompt=[int(x) for x in rng.integers(0, 64, 4)],
                       sampling=SamplingParams(max_new_tokens=30)))
    eng.submit(Request(rid=1, prompt=[int(x) for x in rng.integers(0, 64, 30)],
                       sampling=SamplingParams(max_new_tokens=30)))
    eng.step()
    short, long_ = eng.kv_bytes(0), eng.kv_bytes(1)
    assert 0 < short < long_
    # a full-row charge (what the old implementation reported) is strictly
    # larger than either active request's payload
    full = sum(leaf.nbytes // leaf.shape[ax] for leaf, ax in
               zip(jax.tree.leaves(eng.caches), eng._batch_axes))
    assert long_ < full
    # growing the sequence grows the payload
    before = eng.kv_bytes(0)
    for _ in range(10):
        eng.step()
    assert eng.kv_bytes(0) > before
