"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.ops import ssd_chunked_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.serving.kv_cache import PagedAllocator

pytestmark = pytest.mark.slow  # pallas interpret-mode kernel sweeps

RNG = np.random.default_rng(7)


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("B,Sq,Skv,H,KV,d,window", [
    (2, 256, 256, 4, 2, 64, 0),
    (1, 128, 384, 4, 1, 64, 0),        # kv longer than q (right-aligned)
    (2, 256, 256, 8, 8, 32, 64),       # sliding window, MHA
    (1, 200, 200, 4, 2, 64, 0),        # non-block-multiple (padding path)
    (1, 128, 128, 6, 2, 128, 32),      # GQA 3x, window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Skv, H, KV, d, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, KV, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, KV, d)), dtype)
    out = attention(q, k, v, causal=True, window=window, use_pallas=True,
                    interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True,
                        window=window).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------------ paged attn
@pytest.mark.parametrize("B,H,KV,d,nb,bs,maxb", [
    (3, 8, 2, 64, 16, 16, 6),
    (2, 4, 4, 32, 8, 8, 4),
    (1, 8, 1, 128, 32, 16, 8),
    (4, 2, 2, 64, 12, 32, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, KV, d, nb, bs, maxb, dtype):
    alloc = PagedAllocator(nb, bs)
    ctx = RNG.integers(max(bs // 2, 1), maxb * bs, B)
    table = np.full((B, maxb), -1, np.int32)
    for b in range(B):
        blocks = alloc.allocate(b, int(ctx[b]))
        assert blocks is not None
        table[b, :len(blocks)] = blocks
    q = jnp.asarray(RNG.normal(size=(B, H, d)), dtype)
    kp = jnp.asarray(RNG.normal(size=(nb, bs, KV, d)), dtype)
    vp = jnp.asarray(RNG.normal(size=(nb, bs, KV, d)), dtype)
    tb, cl = jnp.asarray(table), jnp.asarray(ctx, jnp.int32)
    out = paged_decode_attention(q, kp, vp, tb, cl, use_pallas=True,
                                 interpret=True)
    ref = paged_attention_ref(q, kp, vp, tb, cl)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("b,S,H,P,N,Q", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 8, 64, 32, 64),
    (2, 64, 2, 16, 128, 64),
    (1, 512, 2, 64, 64, 128),
])
def test_ssd_scan(b, S, H, P, N, Q):
    x = jnp.asarray(RNG.normal(size=(b, S, H, P)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, H, N)), jnp.float32) * 0.5
    C = jnp.asarray(RNG.normal(size=(b, S, H, N)), jnp.float32) * 0.5
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, S, H)), jnp.float32)
    da = -dt * jnp.asarray(RNG.uniform(0.5, 2.0, size=(b, S, H)), jnp.float32)
    y, h = ssd_chunked_scan(x, B, C, dt, da, chunk=Q, use_pallas=True,
                            interpret=True)
    yr, hr = ssd_scan_ref(x, B, C, dt, da, chunk=Q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4)


def test_ssd_kernel_matches_sequential_recurrence():
    """The chunked kernel must equal the literal per-token recurrence."""
    b, S, H, P, N, Q = 1, 64, 2, 8, 4, 16
    x = jnp.asarray(RNG.normal(size=(b, S, H, P)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, S, H, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, S, H, N)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.3, size=(b, S, H)), jnp.float32)
    da = -dt
    y, h_last = ssd_chunked_scan(x, B, C, dt, da, chunk=Q, use_pallas=True,
                                 interpret=True)
    hs = np.zeros((b, H, P, N), np.float32)
    ys = np.zeros((b, S, H, P), np.float32)
    for t in range(S):
        decay = np.exp(np.asarray(da[:, t]))[..., None, None]
        outer = np.einsum("bhn,bhp->bhpn", np.asarray(B[:, t]),
                          np.asarray(x[:, t] * dt[:, t, :, None]))
        hs = hs * decay + outer
        ys[:, t] = np.einsum("bhn,bhpn->bhp", np.asarray(C[:, t]), hs)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_last), hs, atol=1e-3)


# --------------------------------------------------- model-level pallas
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m", "mixtral-8x7b"])
def test_model_with_pallas_matches_ref(arch):
    from repro.configs import get_config
    from repro.configs.perf import BASELINE, with_overrides
    from repro.models import params as P
    from repro.models.lm import make_model
    cfg = get_config(arch + "-smoke")
    m_ref = make_model(cfg, BASELINE)
    m_pal = make_model(cfg, with_overrides(BASELINE, use_pallas=True))
    params = P.init(jax.random.PRNGKey(0), m_ref.param_specs())
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size).astype(jnp.int32)
    lr, _ = jax.jit(lambda p, b: m_ref.prefill(p, b, 48))(params, {"tokens": toks})
    lp, _ = jax.jit(lambda p, b: m_pal.prefill(p, b, 48))(params, {"tokens": toks})
    rel = float(jnp.max(jnp.abs(lr - lp))) / (float(jnp.max(jnp.abs(lr))) + 1e-9)
    # MoE archs: bf16 noise can flip router top-k, so tolerance is looser
    assert rel < (6e-2 if cfg.num_experts else 2e-2), (arch, rel)
