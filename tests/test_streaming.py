"""Event-driven streaming: per-token events vs. final Request.output
(dense + paged + across live migration), SLO-deadline preemption, the
completions front-end (sync == streamed), and the slo_met falsy-zero fix."""

import pytest

from repro.configs import get_config
from repro.serving import (CompletionRequest, CompletionsAPI, FinishEvent,
                           FirstTokenEvent, InferenceEngine, PreemptEvent,
                           Request, SamplingParams, State, StreamDemux,
                           TokenEvent)
from repro.serving.scheduler import SchedulerConfig, deadline_risk

ARCH = "qwen2-0.5b-smoke"


def _mk(backend="dense", **kw):
    cfg = get_config(ARCH)
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    if backend == "paged":
        kw.setdefault("block_size", 8)
    return cfg, InferenceEngine(cfg, kv_backend=backend, **kw)


def _collect(eng, demux, streamed, events):
    """Run an engine to empty, feeding every step's events through the
    shared demux into per-rid token streams."""
    t = float(len(events))
    while eng.pending() and t < 500:
        st = eng.step(now=t)
        events.extend(st.events)
        for tok in demux.feed(st.events):
            streamed.setdefault(tok.rid, []).append(tok.token)
        t += 1.0


# ------------------------------------------------------------- slo_met fix
def test_slo_met_accepts_zero_ttft_and_tpot():
    """ttft == 0.0 / tpot == 0.0 are legitimate values (first token in the
    arrival step under a logical clock) and must count as met, not be
    misread as missing by an ``(x or default)`` falsy-zero pattern."""
    r = Request(rid=0, prompt=[1], slo_ttft=0.5, slo_tpot=0.5)
    r.arrival = 10.0
    r.t_first_token = 10.0                   # ttft == 0.0
    r.token_times = [10.0, 10.0, 10.0]       # tpot == 0.0
    assert r.ttft == 0.0 and r.tpot == 0.0
    assert r.slo_met()
    # and genuinely-missing ttft still misses a ttft SLO
    r2 = Request(rid=1, prompt=[1], slo_ttft=0.5)
    r2.arrival = 0.0
    assert r2.ttft is None and not r2.slo_met()
    # a real miss still misses
    r3 = Request(rid=2, prompt=[1], slo_ttft=0.5)
    r3.arrival = 0.0
    r3.t_first_token = 2.0
    assert not r3.slo_met()


def test_deadline_risk_needs_two_tokens_and_a_slo():
    a = Request(rid=0, prompt=[1], slo_tpot=1.0)
    a.token_times = [0.0, 5.0]               # tpot 5 >= 1
    b = Request(rid=1, prompt=[1], slo_tpot=1.0)
    b.token_times = [0.0]                    # no tpot yet
    c = Request(rid=2, prompt=[1])           # no SLO
    c.token_times = [0.0, 5.0]
    assert deadline_risk([a, b, c]) == [a]
    assert deadline_risk([a], margin=10.0) == []


# --------------------------------------------------------- event semantics
@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_streamed_tokens_match_output(backend, rng):
    """Tokens streamed via events are identical to the final
    Request.output, for bucketed, chunked, and prefix-cache-hit prompts."""
    cfg, eng = _mk(backend)
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, n)]
               for n in (5, 11, 40, 20)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=6)))
    demux, streamed, events = StreamDemux(), {}, []
    _collect(eng, demux, streamed, events)
    done = {r.rid: r.output for r in eng.finished}
    assert len(done) == len(prompts)
    assert streamed == done
    firsts = [e for e in events if isinstance(e, FirstTokenEvent)]
    finishes = [e for e in events if isinstance(e, FinishEvent)]
    assert sorted(e.rid for e in firsts) == list(range(len(prompts)))
    assert sorted(e.rid for e in finishes) == list(range(len(prompts)))
    for e in finishes:
        assert e.reason == "length" and e.n_tokens == 6
    # per-request TTFT truth: the FirstTokenEvent timestamp
    for e in firsts:
        r = next(r for r in eng.finished if r.rid == e.rid)
        assert r.t_first_token == e.t and e.index == 0


def test_finish_reason_stop_token(rng):
    cfg, eng = _mk()
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
    # greedy-decode once to learn a token it will emit, then stop on it
    eng.submit(Request(rid=0, prompt=list(prompt),
                       sampling=SamplingParams(max_new_tokens=8)))
    ref = eng.run(max_steps=60)[0].output
    eng.finished.clear()
    stop = ref[2]
    eng.submit(Request(rid=1, prompt=list(prompt),
                       sampling=SamplingParams(max_new_tokens=8,
                                               stop_token=stop)))
    demux, streamed, events = StreamDemux(), {}, []
    _collect(eng, demux, streamed, events)
    (req,) = eng.finished
    assert req.finish_reason == "stop"
    assert streamed[1] == req.output == ref[:3]
    fin = [e for e in events if isinstance(e, FinishEvent)]
    assert fin[-1].reason == "stop"


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_stream_survives_mid_decode_migration(backend, rng):
    """A request migrated mid-decode keeps streaming from its new replica:
    the merged two-replica event stream carries every output token exactly
    once — no duplicates, no gaps — and matches an unmigrated run."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk(backend, seed=3)
    _, eng_b = _mk(backend, seed=3)
    eng_b.params = eng_a.params
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]

    ref_eng = _mk(backend, seed=3)[1]
    ref_eng.params = eng_a.params
    ref_eng.submit(Request(rid=0, prompt=list(prompt),
                           sampling=SamplingParams(max_new_tokens=8)))
    ref = ref_eng.run(max_steps=60)[0].output

    req = Request(rid=0, prompt=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng_a.submit(req)
    demux, streamed, events = StreamDemux(), {}, []
    for t in range(4):                       # prefill + a few decode steps
        st = eng_a.step(now=float(t))
        events.extend(st.events)
        for tok in demux.feed(st.events):
            streamed.setdefault(tok.rid, []).append(tok.token)
    assert req.state is State.DECODE and len(streamed[0]) >= 2
    mgr = MigrationManager()
    ev = mgr.migrate(eng_a, eng_b, rid=0, now=4.0)
    assert ev is not None
    # the source's handoff preempt surfaces when its events are drained
    moved = eng_a.drain_events()
    events.extend(moved)
    assert any(isinstance(e, PreemptEvent) and e.reason == "migrate"
               for e in moved)
    _collect(eng_b, demux, streamed, events)
    done = eng_b.finished[0]
    assert done.migrations == 1
    assert streamed[0] == done.output == ref
    toks = [e for e in events if isinstance(e, TokenEvent) and e.rid == 0]
    assert [e.index for e in toks] == list(range(len(ref))), \
        "token indices must be gapless and duplicate-free across migration"


def test_demux_drops_rollback_reemission():
    """After a migration rollback-requeue the re-serving replica re-emits
    earlier indices; the demux keeps the downstream stream append-only."""
    d = StreamDemux()
    out = d.feed([TokenEvent(t=0.0, rid=7, token=11, index=0),
                  TokenEvent(t=1.0, rid=7, token=12, index=1)])
    assert [e.token for e in out] == [11, 12]
    # rollback: replica restarts from index 0 (greedy => same tokens)
    out = d.feed([PreemptEvent(t=2.0, rid=7, reason="requeued"),
                  TokenEvent(t=3.0, rid=7, token=11, index=0),
                  TokenEvent(t=4.0, rid=7, token=12, index=1),
                  TokenEvent(t=5.0, rid=7, token=13, index=2)])
    assert [e.token for e in out] == [13]
    with pytest.raises(RuntimeError, match="stream gap"):
        d.feed([TokenEvent(t=6.0, rid=7, token=99, index=9)])


# ----------------------------------------------------------- SLO preemption
def test_deadline_risk_decode_displaces_fresh_prefill(rng):
    """With the SLO guard on, a decode row whose TPOT is past deadline
    withholds admission and preempts the freshest mid-prefill row back to
    the queue head; the preempted request still completes with unchanged
    greedy output once the pressure clears."""
    cfg, eng = _mk(sched=SchedulerConfig(slo_guard=True,
                                         slo_guard_patience=1))
    short = [int(x) for x in rng.integers(0, cfg.vocab_size, 5)]
    long = [int(x) for x in rng.integers(0, cfg.vocab_size, 40)]  # chunked

    ref_eng = _mk()[1]
    ref_eng.params = eng.params
    ref_eng.submit(Request(rid=1, prompt=list(long),
                           sampling=SamplingParams(max_new_tokens=4)))
    ref = ref_eng.run(max_steps=60)[0].output

    a = Request(rid=0, prompt=list(short),
                sampling=SamplingParams(max_new_tokens=8), slo_tpot=2.0)
    b = Request(rid=1, prompt=list(long),
                sampling=SamplingParams(max_new_tokens=4))
    eng.submit(a, now=0.0)
    eng.step(now=0.0)                        # A: prefill + first token
    eng.step(now=1.0)                        # A decoding, tpot == 1 < 2
    eng.submit(b, now=2.0)
    st = eng.step(now=2.0)                   # no risk: B admitted, chunk 1
    assert st.n_prefill == 1 and b.state is State.PREFILL
    eng.step(now=9.0)                        # A's token lands late (gap)
    # the guard sees A's tpot (9-0)/3 = 3 >= 2 at the *next* step's check
    st = eng.step(now=10.0)
    assert st.preempted == 1 and eng.preemptions == 1
    assert b.state is State.QUEUED and b.preemptions == 1
    assert any(isinstance(e, PreemptEvent)
               and e.reason == "slo-decode-pressure" for e in st.events)
    assert st.n_prefill == 0, "admission must be withheld under risk"
    # pressure clears as A's TPOT recovers / A finishes; B then re-admits
    t = 10.0
    while eng.pending() and t < 100.0:
        eng.step(now=t)
        t += 1.0
    done = {r.rid: r for r in eng.finished}
    assert set(done) == {0, 1}
    assert done[1].output == ref, "preemption must not corrupt the output"


# ------------------------------------------------------------ the frontend
def test_completions_api_sync_and_stream_match(rng):
    cfg, eng = _mk()
    api = CompletionsAPI(eng, model=ARCH)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 11)]
    resp = api.create(CompletionRequest(prompt=list(prompt), model=ARCH,
                                        max_tokens=6),
                      now=0.0)
    assert resp.choices[0].finish_reason == "length"
    assert len(resp.choices[0].tokens) == 6
    assert resp.usage.total_tokens == 11 + 6
    assert resp.x_ttft is not None

    chunks = list(api.stream(CompletionRequest(prompt=list(prompt), model=ARCH,
                                               max_tokens=6, stream=True),
                             now=100.0))
    toks = [c.choices[0]["tokens"][0] for c in chunks
            if c.choices[0]["tokens"]]
    assert toks == resp.choices[0].tokens, \
        "streaming and sync must serve byte-identical completions"
    assert chunks[-1].choices[0]["finish_reason"] == "length"
    sse = chunks[0].to_sse()
    assert sse.startswith("data: ") and sse.endswith("\n\n")


def test_completions_api_interleaved_streams(rng):
    """Concurrent stream() generators share one backend: each pump fans
    events to every open stream, frames interleave, streams stay exact."""
    cfg, eng = _mk()
    api = CompletionsAPI(eng)
    gens, want = [], []
    for i in range(3):
        p = [int(x) for x in rng.integers(0, cfg.vocab_size, 6 + i)]
        want.append(p)
        gens.append(api.stream(CompletionRequest(prompt=p, model="repro-lm",
                                                 max_tokens=5),
                               now=0.0))
    got = {i: [] for i in range(3)}
    live = list(enumerate(gens))
    while live:
        for i, g in list(live):
            try:
                chunk = next(g)
            except StopIteration:
                live.remove((i, g))
                continue
            got[i].extend(chunk.choices[0]["tokens"])
    done = sorted(eng.finished, key=lambda r: r.rid)
    assert [got[i] for i in range(3)] == [r.output for r in done]


def test_completions_api_rejects_oversized_prompt(rng):
    cfg, eng = _mk()
    api = CompletionsAPI(eng)
    resp = api.create(CompletionRequest(
        prompt=[1] * (eng.max_len + 40), model="repro-lm",
        max_tokens=4), now=0.0)
    assert resp.choices[0].finish_reason == "rejected"
    assert resp.choices[0].tokens == []
    chunks = list(api.stream(CompletionRequest(
        prompt=[1] * (eng.max_len + 40), model="repro-lm",
        max_tokens=4), now=0.0))
    assert len(chunks) == 1
    assert chunks[0].choices[0]["finish_reason"] == "rejected"


def test_completions_api_over_orchestrator(rng):
    """The same front-end backed by the cluster: events are forwarded
    through orchestrator replica steps."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    cfg = get_config(ARCH)
    orch = Orchestrator(
        lambda: InferenceEngine(cfg, capacity=2, max_len=48, buckets=(8, 16),
                                seed=11),
        OrchestratorConfig(min_replicas=1, hpa=HPAConfig(
            metric="queue", target=4.0, max_replicas=2, tolerance=0.0,
            stabilization_s=0.0, scale_down_cooldown_s=1e9)))
    api = CompletionsAPI(orch)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
    resp = api.create(CompletionRequest(prompt=prompt, model="repro-lm",
                                        max_tokens=5), now=0.0)
    assert len(resp.choices[0].tokens) == 5
    assert resp.choices[0].finish_reason == "length"


def test_stream_survives_disaggregated_handoff(rng):
    """Prefill->decode handoff is a mid-flight migration: the pool-wide
    event stream hands each request from the prefill engine's first token
    to the decode engine's tokens with no duplicated or dropped indices."""
    from repro.core.disaggregation import DisaggConfig, DisaggregatedServer
    cfg = get_config(ARCH)

    def mk():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               seed=21)

    ref_eng = mk()
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
               for _ in range(3)]
    for i, p in enumerate(prompts):
        ref_eng.submit(Request(rid=i, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=6)))
    ref = {r.rid: r.output for r in ref_eng.run(max_steps=100)}

    srv = DisaggregatedServer(mk, DisaggConfig(prefill_engines=1,
                                               decode_engines=2))
    srv.prefill_pool[0].params = ref_eng.params
    for e in srv.decode_pool:
        e.params = ref_eng.params
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=6)))
    demux, streamed, preempts = StreamDemux(), {}, []
    t = 0.0
    while srv.pending() and t < 200:
        srv.step(now=t)
        evs = srv.drain_events()
        preempts += [e for e in evs if isinstance(e, PreemptEvent)]
        for tok in demux.feed(evs):
            streamed.setdefault(tok.rid, []).append(tok.token)
        t += 1.0
    done = {r.rid: r.output for r in srv.run(max_steps=10)}
    assert streamed == done == ref
    assert len(preempts) == 3 and all(e.reason == "migrate"
                                      for e in preempts)
