"""Sharding-rule resolution unit tests (no real 256-device mesh needed)."""
from jax.sharding import PartitionSpec

from repro.distributed.sharding import DEFAULT_RULES, Sharder, rules_for


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _sh(shape=None, rules=None):
    return Sharder(FakeMesh(shape or {"data": 16, "model": 16}),
                   rules or dict(DEFAULT_RULES))


def test_mlp_and_vocab_shard_over_model():
    sh = _sh()
    assert sh.spec_for((896, 4864), ("embed", "mlp")) == PartitionSpec(None, "model")
    assert sh.spec_for((151936, 896), ("vocab", "embed")) == PartitionSpec("model")


def test_nondivisible_heads_replicate():
    sh = _sh()
    # qwen2: 14 heads, head_dim 64 — no fallback onto head_dim (see rules)
    assert sh.spec_for((896, 14, 64), ("embed", "heads", "qkv")) == PartitionSpec()


def test_divisible_heads_shard():
    sh = _sh()
    assert sh.spec_for((5376, 32, 128), ("embed", "heads", "qkv")) == \
        PartitionSpec(None, "model")


def test_experts_take_model_before_moe_mlp():
    sh = _sh()
    # qwen3: 128 experts divisible by 16
    spec = sh.spec_for((128, 2048, 768), ("experts", "embed", "moe_mlp"))
    assert spec == PartitionSpec("model")
    # mixtral: 8 experts not divisible -> falls to expert-internal d_ff
    spec = sh.spec_for((8, 4096, 14336), ("experts", "embed", "moe_mlp"))
    assert spec == PartitionSpec(None, None, "model")


def test_batch_fuses_pod_and_data():
    sh = _sh({"pod": 2, "data": 16, "model": 16})
    spec = sh.spec_for((256, 4096, 896), ("batch", "act_seq", "embed"))
    assert spec == PartitionSpec(("pod", "data"))


def test_batch_one_falls_back_to_kv_sequence():
    sh = _sh()
    spec = sh.spec_for((1, 524288, 16, 128), ("batch", "act_kv", "kv_heads", "qkv"))
    assert spec == PartitionSpec(None, "data", "model")


def test_act_kv_takes_model_when_kv_heads_cannot():
    sh = _sh()
    # qwen3-moe decode: kv=4 < 16 => cache length takes model (HBM fix)
    spec = sh.spec_for((128, 32768, 4, 128), ("batch", "act_kv", "kv_heads", "qkv"))
    assert spec == PartitionSpec("data", "model")


def test_zero1_adds_free_axes():
    from repro.models.params import ParamSpec
    sh = _sh()
    s = ParamSpec((4864, 896), ("mlp", "embed"))
    # param sharding (model on mlp) + data placed on the largest free dim
    assert sh.zero1_spec(s) == PartitionSpec("model", "data")
    # zero3: weights store data-sharded; moments additionally take model
    s2 = ParamSpec((48, 896, 4864), ("layers", "embed", "mlp"))
    sh3 = _sh(rules=rules_for("zero3"))
    assert sh3.zero1_spec(s2) == PartitionSpec(None, "model", "data")


def test_zero3_rules_shard_weight_dims_over_data():
    sh = _sh(rules=rules_for("zero3"))
    # FSDP storage: widest weight dim over data; the stacked layer axis is
    # NOT used (group counts rarely divide the data axis — DESIGN.md §6)
    spec = sh.spec_for((48, 896, 4864), ("layers", "embed", "mlp"))
    assert spec == PartitionSpec(None, None, "data")
    assert sh.spec_for((262144, 5376), ("vocab", "embed")) == \
        PartitionSpec("model")


def test_dp_rules_fuse_all_axes_on_batch():
    sh = _sh({"pod": 2, "data": 16, "model": 16}, rules_for("dp"))
    spec = sh.spec_for((512, 4096, 896), ("batch", "act_seq", "embed"))
    assert spec == PartitionSpec(("pod", "data", "model"))
    # weights replicated
    assert sh.spec_for((896, 4864), ("embed", "mlp")) == PartitionSpec()


def test_no_mesh_sharder_is_noop():
    import jax.numpy as jnp
    sh = Sharder(None)
    x = jnp.ones((4, 4))
    assert sh(x, ("batch", "embed")) is x
    assert sh.spec_shardings({"w": None.__class__}) is None or True
