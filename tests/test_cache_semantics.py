"""Property tests for KV-cache write/mask semantics (layers.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import layers as L

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.integers(1, 6), st.integers(4, 32), st.integers(0, 2**31 - 1))
def test_decode_write_is_scatter_equivalent(B, Lc, seed):
    """The select-based write == a literal per-row scatter."""
    rng = np.random.default_rng(seed)
    KV, d = 2, 4
    cache = {"k": jnp.asarray(rng.normal(size=(B, Lc, KV, d)), jnp.float32),
             "v": jnp.asarray(rng.normal(size=(B, Lc, KV, d)), jnp.float32)}
    k = jnp.asarray(rng.normal(size=(B, 1, KV, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 1, KV, d)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 3 * Lc, B), jnp.int32)
    out = L.cache_write_decode(cache, k, v, pos, ring=False)
    ref_k = np.asarray(cache["k"]).copy()
    ref_v = np.asarray(cache["v"]).copy()
    for b in range(B):
        s = int(pos[b]) % Lc
        ref_k[b, s] = np.asarray(k)[b, 0]
        ref_v[b, s] = np.asarray(v)[b, 0]
    np.testing.assert_array_equal(np.asarray(out["k"]), ref_k)
    np.testing.assert_array_equal(np.asarray(out["v"]), ref_v)


@settings(**SETTINGS)
@given(st.integers(2, 5), st.integers(4, 16), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
def test_ring_prefill_keeps_last_window_of_valid_tokens(B, W, S, seed):
    """Ring cache after a right-padded prefill exposes exactly the last
    min(true_len, W) valid positions."""
    rng = np.random.default_rng(seed)
    KV, d = 1, 4
    true_len = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, d)), jnp.float32)
    empty = {"k": jnp.zeros((B, W, KV, d)), "v": jnp.zeros((B, W, KV, d)),
             "pos": jnp.full((B, W), -1, jnp.int32)}
    out = L.cache_write_prefill(empty, k, v, ring=True, window=W,
                                true_len=true_len)
    pos = np.asarray(out["pos"])
    for b in range(B):
        t = int(true_len[b])
        expect = set(range(max(0, t - W), t))
        got = set(int(p) for p in pos[b] if p >= 0)
        assert got == expect, (b, t, W, got, expect)
        # stored k matches source rows at their canonical slots
        for s_i, p in enumerate(pos[b]):
            if p >= 0:
                np.testing.assert_array_equal(
                    np.asarray(out["k"])[b, s_i], np.asarray(k)[b, int(p)])


@settings(**SETTINGS)
@given(st.integers(2, 5), st.integers(4, 16), st.integers(0, 2**31 - 1))
def test_cache_valid_mask_visibility(B, W, seed):
    """Ring visibility: slot visible iff 0 <= pos_slot <= pos and within
    the window."""
    rng = np.random.default_rng(seed)
    sp = jnp.asarray(rng.integers(-1, 60, (B, W)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 60, B), jnp.int32)
    m = L.cache_valid_mask({"k": jnp.zeros((B, W, 1, 1)), "pos": sp}, pos,
                           ring=True, window=W)
    ref = (np.asarray(sp) >= 0) & (np.asarray(sp) <= np.asarray(pos)[:, None]) \
        & (np.asarray(sp) > np.asarray(pos)[:, None] - W)
    np.testing.assert_array_equal(np.asarray(m), ref)
