"""Property tests: prefix-cache / block-allocator invariants and the
paged-gather oracle (hypothesis-guarded like test_properties.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.kv_cache import PagedAllocator, paged_gather, paged_write_chunk
from repro.serving.prefix_cache import PrefixCache

SETTINGS = dict(max_examples=40, deadline=None)


# ------------------------------------------------------------ block space
@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 40),
                          st.integers(0, 7)), min_size=1, max_size=60),
       st.integers(4, 32), st.integers(2, 8))
def test_prefix_cache_block_invariants(ops, num_blocks, bs):
    """Random alloc / release / match / insert / adopt interleavings:
    refcounts never go negative, no block is double-owned, double-freed or
    double-mapped within a row, eviction never reclaims a referenced block,
    and the pool never leaks — including through the migration adopt path
    (``adopt_blocks``), whose refusal must leave the cache untouched."""
    pc = PrefixCache(num_blocks, bs)
    rng = np.random.default_rng(0)
    live: dict[int, list[int]] = {}     # seq -> owned blocks
    seqs: dict[int, list[int]] = {}     # seq -> tokens
    sid = 0
    for op, n, tok in ops:
        if op == 4:                      # adopt a migrated sequence
            n_valid = min(n, 4 * bs - 1)
            seq = [int(x) for x in rng.integers(0, 8, n_valid)]
            before = (pc.free_blocks, pc.evictable_blocks,
                      pc.hit_tokens, pc.miss_tokens)
            plan = pc.adopt_blocks(seq, n_valid, extra_horizon=tok % 3)
            if plan is None:
                # a refused adopt is side-effect free
                assert before == (pc.free_blocks, pc.evictable_blocks,
                                  pc.hit_tokens, pc.miss_tokens)
            else:
                blocks, n_keep = plan
                assert len(blocks) == -(-n_valid // bs)
                assert 0 <= n_keep < len(blocks), \
                    "the tail block must always be transferred"
                assert len(set(blocks)) == len(blocks), "double-mapped row"
                assert all(pc.ref(b) > 0 for b in blocks)
                # fresh blocks are private until this row shares them
                assert all(pc.ref(b) == 1 for b in blocks[n_keep:])
                assert (pc.hit_tokens, pc.miss_tokens) == before[2:], \
                    "adopt must not count as served-prompt hit/miss"
                pc.insert(seq, blocks, (n_valid // bs) * bs)  # donation
                live[sid] = blocks
                seqs[sid] = seq
                sid += 1
            pc.check_invariants()
            continue
        if op == 0:                      # allocate a fresh sequence
            got = pc.allocate(min(n, 6))
            if got is not None:
                assert len(set(got)) == len(got)
                owned = [b for bl in live.values() for b in bl]
                for b in got:
                    # eviction may recycle cached blocks but never ones a
                    # live sequence still references
                    assert b not in owned, "evicted a referenced block"
                live[sid] = got
                seqs[sid] = [int(x) for x in
                             rng.integers(0, 8, len(got) * bs)]
                sid += 1
        elif op == 1 and live:           # retire: insert + release
            victim = next(iter(live))
            blocks = live.pop(victim)
            toks = seqs.pop(victim)
            n_valid = min(len(toks), n * bs // 4 + 1)
            pc.insert(toks, blocks, n_valid)
            pc.release(blocks)
        elif op == 2:                    # match a random prompt
            prompt = [int(x) for x in rng.integers(0, 8, max(n, 2))]
            blocks, hit = pc.match(prompt)
            assert hit <= len(prompt) - 1
            assert hit >= (len(blocks) - 1) * bs
            live[sid] = blocks           # hold refs like an admitted row
            seqs[sid] = prompt[:hit] if hit else []
            sid += 1
        elif op == 3 and live:           # plain release (no insert)
            victim = next(iter(live))
            pc.release(live.pop(victim))
            del seqs[victim]
        pc.check_invariants()
    for s in list(live):
        pc.release(live.pop(s))
    pc.check_invariants()
    # nothing referenced: the whole pool is free or evictable cache
    assert pc.free_blocks + pc.evictable_blocks == num_blocks


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(1, 60), st.integers(1, 20))
def test_prefix_cache_match_roundtrip(bs, plen, extra):
    """Insert a sequence then match it again: every full block (and the
    partial tail) of the prompt is found, capped so the final token is
    always recomputed."""
    pc = PrefixCache(64, bs)
    rng = np.random.default_rng(plen * 31 + bs)
    toks = [int(x) for x in rng.integers(0, 50, plen)]
    nblk = -(-plen // bs)
    blocks = pc.allocate(nblk)
    pc.insert(toks, blocks, plen)
    pc.release(blocks)
    got, hit = pc.match(list(toks) + [int(x) for x in
                                      rng.integers(50, 60, extra)])
    # the continuation diverges after plen, so the hit is exactly the
    # indexed prefix (full blocks + tail), never more
    assert hit == plen
    assert len(got) == nblk
    # matched blocks are referenced: a second allocation sweep cannot
    # reclaim them
    assert all(pc.ref(b) == 1 for b in got)
    pc.release(got)
    pc.check_invariants()


@settings(**SETTINGS)
@given(st.integers(2, 8), st.integers(2, 40))
def test_prefix_cache_caps_full_prompt_hit(bs, plen):
    """A prompt fully covered by the cache still recomputes >= 1 token."""
    pc = PrefixCache(64, bs)
    toks = list(range(plen))
    blocks = pc.allocate(-(-plen // bs))
    pc.insert(toks, blocks, plen)
    pc.release(blocks)
    got, hit = pc.match(list(toks))
    assert hit <= plen - 1
    pc.release(got)


def test_prefix_cache_cow_flags():
    pc = PrefixCache(8, 4)
    (a,) = pc.allocate(1)
    assert not pc.needs_cow(a)          # private, uncached
    pc.incref(a)
    assert pc.needs_cow(a)              # shared
    pc.decref(a)
    pc.insert([1, 2, 3], [a], 3)        # partial tail retained by the index
    assert pc.needs_cow(a)
    with pytest.raises(ValueError):
        pc.decref(99)                   # unreferenced block: never goes < 0


def test_paged_allocator_extend_unknown_rid():
    a = PagedAllocator(8, 4)
    with pytest.raises(ValueError, match="unknown rid"):
        a.extend(123, 10)


# ------------------------------------------------------------ paged gather
@settings(**SETTINGS)
@given(st.integers(1, 4), st.integers(2, 16), st.integers(1, 70),
       st.integers(0, 2**31 - 1))
def test_paged_gather_matches_dense_oracle(B, bs, max_len, seed):
    """paged_gather == a literal per-token dense gather for arbitrary
    lengths — including max_len not a multiple of the block size (the old
    floor dropped the tail tokens)."""
    rng = np.random.default_rng(seed)
    KV, d = 2, 4
    max_blk = -(-max_len // bs) + rng.integers(0, 3)
    nb = B * max_blk + 1
    pool = jnp.asarray(rng.normal(size=(nb, bs, KV, d)), jnp.float32)
    lens = rng.integers(0, max_len + 1, B)
    table = np.full((B, max_blk), -1, np.int32)
    free = list(range(nb))
    rng.shuffle(free)
    for b in range(B):
        for j in range(-(-int(lens[b]) // bs)):
            table[b, j] = free.pop()
    out = np.asarray(paged_gather(pool, jnp.asarray(table), max_len))
    assert out.shape == (B, max_len, KV, d)
    ref = np.zeros((B, max_len, KV, d), np.float32)
    for b in range(B):
        for t in range(max_len):
            blk = table[b, t // bs]
            if blk >= 0:
                ref[b, t] = np.asarray(pool)[blk, t % bs]
    np.testing.assert_array_equal(out, ref)


@settings(**SETTINGS)
@given(st.integers(1, 3), st.integers(2, 8), st.integers(1, 12),
       st.integers(0, 2**31 - 1))
def test_paged_write_chunk_is_scatter_equivalent(B, bs, C, seed):
    """The vectorised chunk append == a per-token scatter; idle rows and
    pad positions are exact no-ops."""
    rng = np.random.default_rng(seed)
    KV, d = 1, 4
    max_blk = 8
    nb = B * max_blk
    kp = jnp.asarray(rng.normal(size=(nb, bs, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, KV, d)), jnp.float32)
    table = np.full((B, max_blk), -1, np.int32)
    pos0 = rng.integers(0, max_blk * bs - C, B).astype(np.int32)
    nval = rng.integers(0, C + 1, B).astype(np.int32)
    free = list(range(nb))
    for b in range(B):
        for j in range(-(-int(pos0[b] + nval[b]) // bs)):
            table[b, j] = free.pop()
    k = jnp.asarray(rng.normal(size=(B, C, KV, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, C, KV, d)), jnp.float32)
    ok, ov = paged_write_chunk(kp, vp, jnp.asarray(table),
                               jnp.asarray(pos0), jnp.asarray(nval), k, v)
    ref_k = np.asarray(kp).copy()
    for b in range(B):
        for j in range(int(nval[b])):
            p = int(pos0[b]) + j
            ref_k[table[b, p // bs], p % bs] = np.asarray(k)[b, j]
    np.testing.assert_array_equal(np.asarray(ok), ref_k)
