"""Cluster cache directory: event-sink tracking, staleness tolerance,
scale-down invalidation, migration-donation visibility, and the
conservative-subset property (hypothesis-guarded)."""
import numpy as np
import pytest

from repro.core.cache_directory import ClusterCacheDirectory
from repro.core.loadbalancer import LoadBalancer
from repro.serving.prefix_cache import PrefixCache


def _fill(pc: PrefixCache, tokens: list[int]) -> None:
    """Cache ``tokens`` in ``pc`` the way a retiring row would."""
    nblk = -(-len(tokens) // pc.block_size)
    blocks = pc.allocate(nblk)
    assert blocks is not None
    pc.insert(tokens, blocks, len(tokens))
    pc.release(blocks)


def _entry_chains(pc: PrefixCache) -> set[int]:
    return {e.chain for e in pc._entry.values() if e.chain is not None}


class _R:
    def __init__(self, lb_id, load=0.0):
        self.lb_id, self.load = lb_id, load


# ------------------------------------------------------------- delta stream
def test_directory_tracks_inserts_and_walks_beyond_first_block():
    d = ClusterCacheDirectory()
    a, b = PrefixCache(16, 4), PrefixCache(16, 4)
    a.attach_sink(d, 0)
    b.attach_sink(d, 1)
    common = [1, 2, 3, 4]                     # shared first block
    _fill(a, common + [5, 6, 7, 8])           # tenant A: 2 blocks deep
    _fill(b, common + [9, 10, 11, 12])        # tenant B: diverges at block 2
    # first block is on both; the deeper walk tells the tenants apart
    assert d.overlaps(common + [5, 6, 7, 8, 99], 4) == {0: 8, 1: 4}
    assert d.overlaps(common + [9, 10, 11, 12, 99], 4) == {0: 4, 1: 8}
    # an unknown prompt overlaps nothing
    assert d.overlaps([70, 71, 72, 73, 74], 4) == {}
    # the last prompt token never counts (it must be recomputed)
    assert d.overlaps(common, 4) == {}


def test_directory_eviction_deltas_flow():
    d = ClusterCacheDirectory()
    pc = PrefixCache(4, 4)
    pc.attach_sink(d, 7)
    _fill(pc, list(range(8)))                 # 2 cached blocks
    assert len(d.claimed(7)) == 2
    # allocating the whole pool evicts the cached blocks -> evict deltas
    got = pc.allocate(4)
    assert got is not None
    pc.release(got)
    assert d.claimed(7) == set()
    assert d.stats.evicts == 2


def test_directory_staleness_and_reconcile_repair():
    """Lost evict events leave stale claims; routing on them is still safe
    (the replica just misses), and reconciliation repairs the view."""
    d = ClusterCacheDirectory()
    pc = PrefixCache(4, 4)
    pc.attach_sink(d, 0)
    seq = list(range(8))
    _fill(pc, seq)
    pc.detach_sink()                          # simulate a lossy event stream
    got = pc.allocate(4)                      # evicts both cached blocks
    pc.release(got)
    # directory still claims content the replica evicted: stale, not wrong
    assert len(d.claimed(0)) == 2
    assert d.overlaps(seq + [99], 4) == {0: 8}
    # ...the replica itself serves correctly regardless of the stale claim
    assert pc.lookup(seq + [99]) == 0
    pc.attach_sink(d, 0)
    dropped, added = d.reconcile(0, pc.reachable_chains())
    assert (dropped, added) == (2, 0)
    assert d.claimed(0) == set()
    assert d.overlaps(seq + [99], 4) == {}


def test_directory_orphaned_descendants_repaired_by_reconcile():
    """Evicting a parent block orphans its descendants: they still hold
    pool blocks (delta stream keeps them claimed) but cannot be served.
    reachable_chains excludes them, so reconcile trims the claim."""
    pc = PrefixCache(8, 4)
    d = ClusterCacheDirectory()
    pc.attach_sink(d, 0)
    _fill(pc, list(range(12)))                # chain of 3 blocks
    # evict exactly the root block (oldest in LRU)
    root_block = next(e.block for e in pc._entry.values() if e.parent == 0)
    pc._lru.pop(root_block)
    pc._uncache(root_block)
    pc._free.append(root_block)
    pc.check_invariants()
    claimed = d.claimed(0)
    reach = pc.reachable_chains()
    assert reach == set()                     # nothing servable from the root
    assert len(claimed) == 2                  # orphans still claimed (stale)
    assert claimed == _entry_chains(pc)       # ...but conservative vs _entry
    d.reconcile(0, pc.reachable_chains())
    assert d.claimed(0) == set()


def test_directory_drop_replica_and_intents():
    d = ClusterCacheDirectory()
    seq = list(range(9))
    d.announce(1, seq, 4)                     # routing intent, nothing cached
    assert d.overlaps(seq, 4) == {1: 8}
    # committed view unaffected by intents
    assert d.claimed(1) == set()
    d.drop_replica(1)
    assert d.overlaps(seq, 4) == {}
    # reconcile also clears intents (the request either committed or died)
    d.announce(2, seq, 4)
    d.reconcile(2, set())
    assert d.overlaps(seq, 4) == {}


# ------------------------------------------------------------- LB policy
def test_lb_directory_policy_blends_overlap_and_load():
    d = ClusterCacheDirectory()
    pc = PrefixCache(16, 4)
    pc.attach_sink(d, 0)
    seq = list(range(12))
    _fill(pc, seq)
    lb = LoadBalancer("directory", directory=d, directory_load_weight=4.0)
    rs = [_R(0), _R(1)]
    prompt = seq + [99]

    def load(r):
        return r.load
    assert lb.pick(rs, load=load, tokens=prompt, block_size=4).lb_id == 0
    # 12 cached tokens are worth 3 units of load at weight 4: beyond that
    # the cold replica wins — locality never creates a hotspot
    rs[0].load = 2.9
    assert lb.pick(rs, load=load, tokens=prompt, block_size=4).lb_id == 0
    rs[0].load = 3.1
    assert lb.pick(rs, load=load, tokens=prompt, block_size=4).lb_id == 1
    # no tokens / cold directory degrade to least-loaded
    assert lb.pick(rs, load=load).lb_id == 1
    assert lb.pick(rs, load=load, tokens=[500, 501], block_size=4).lb_id == 1


# ------------------------------------------------- orchestrator integration
def _paged_orchestrator(policy: str, n_replicas: int = 2,
                        max_replicas: int = 2):
    from repro.configs import get_config
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.serving import InferenceEngine
    from repro.serving.scheduler import SchedulerConfig

    cfg = get_config("qwen2-0.5b-smoke")

    def mk():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               kv_backend="paged", block_size=8,
                               sched=SchedulerConfig(max_prefill_per_step=2))

    ocfg = OrchestratorConfig(
        min_replicas=n_replicas, max_replicas=max_replicas, lb_policy=policy,
        hpa=HPAConfig(metric="queue", target=4.0, min_replicas=1,
                      max_replicas=max_replicas, stabilization_s=2.0,
                      scale_down_cooldown_s=2.0),
        control_every_steps=2, directory_reconcile_every=2)
    return Orchestrator(mk, ocfg), cfg


@pytest.mark.slow
def test_directory_scale_down_invalidation_and_consistency():
    """Engines' caches stream into the orchestrator directory; a drained
    replica's claims disappear with it, and surviving claims stay a subset
    of what each replica's index retains."""
    from repro.serving import Request, SamplingParams

    orch, cfg = _paged_orchestrator("directory", n_replicas=2, max_replicas=2)
    rng = np.random.default_rng(0)
    sys_prefix = [int(x) for x in rng.integers(0, cfg.vocab_size, 16)]
    t = 0.0
    for rid in range(8):
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size, 4)]
        orch.submit(Request(rid=rid, prompt=sys_prefix + tail,
                            sampling=SamplingParams(max_new_tokens=4)), now=t)
    while orch.pending() and t < 500:
        orch.step(now=t)
        t += 1.0
    done = len(orch.finished) + sum(len(e.finished) for e in orch.engines)
    assert done == 8
    live_ids = {e.lb_id for e in orch.engines}
    # queue drained to zero -> the HPA scaled down; departed replicas must
    # have been invalidated
    assert orch.directory.replicas() <= live_ids
    for e in orch.engines:
        assert orch.directory.claimed(e.lb_id) <= _entry_chains(e.prefix)
    # routing still works post-churn and prefers a warm replica
    probe = sys_prefix + [1, 2, 3]
    ov = orch.directory.overlaps(probe, 8)
    assert ov and max(ov.values()) >= 8


@pytest.mark.slow
def test_migration_donation_is_routable():
    """After a migration, the destination's donated blocks are claimed in
    the directory — the next same-prefix request routes to the adopter."""
    from repro.configs import get_config
    from repro.core.migration import MigrationManager
    from repro.serving import InferenceEngine, Request, SamplingParams
    from repro.serving.scheduler import SchedulerConfig

    cfg = get_config("qwen2-0.5b-smoke")
    d = ClusterCacheDirectory()

    def mk(lb_id):
        e = InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                            kv_backend="paged", block_size=8,
                            sched=SchedulerConfig(max_prefill_per_step=2))
        e.lb_id = lb_id
        e.attach_cache_directory(d, lb_id)
        return e

    src, dst = mk(0), mk(1)
    dst.params = src.params
    rng = np.random.default_rng(1)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 20)]
    src.submit(Request(rid=0, prompt=prompt,
                       sampling=SamplingParams(max_new_tokens=12)))
    for _ in range(6):                        # prefill + a few decode steps
        src.step()
    seq = src.migration_sequence(0)
    mgr = MigrationManager()
    ev = mgr.migrate(src, dst, 0, 0.0, 0, 1)
    assert ev is not None
    # the adopter's donated full blocks are immediately routable
    ov = d.overlaps(seq + [1], 8)
    assert ov.get(1, 0) >= 8 * (len(seq) // 8 - 1)
    assert d.claimed(1) <= _entry_chains(dst.prefix)
    # extraction donated the source row's blocks to the source index too
    assert d.claimed(0) <= _entry_chains(src.prefix)
    dst.run(max_steps=200)
    assert len(dst.finished) == 1
    src.prefix.check_invariants()
    dst.prefix.check_invariants()


@pytest.mark.slow
def test_disagg_decode_routing_by_directory():
    """The disaggregated decode pool routes handoffs by directory overlap:
    same-prefix requests adopt onto the decode replica already caching the
    sequence, and every request still completes."""
    from repro.configs import get_config
    from repro.core.disaggregation import DisaggConfig, DisaggregatedServer
    from repro.serving import InferenceEngine, Request, SamplingParams
    from repro.serving.scheduler import SchedulerConfig

    cfg = get_config("qwen2-0.5b-smoke")

    def mk():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               kv_backend="paged", block_size=8,
                               sched=SchedulerConfig(max_prefill_per_step=2))

    srv = DisaggregatedServer(mk, DisaggConfig(prefill_engines=1,
                                               decode_engines=2,
                                               lb_policy="directory"))
    rng = np.random.default_rng(2)
    sys_prefix = [int(x) for x in rng.integers(0, cfg.vocab_size, 16)]
    for rid in range(6):
        tail = [int(x) for x in rng.integers(0, cfg.vocab_size, 4)]
        srv.submit(Request(rid=rid, prompt=sys_prefix + tail,
                           sampling=SamplingParams(max_new_tokens=6)))
    done = srv.run(max_steps=400)
    assert len(done) == 6
    assert srv.migrations.succeeded >= 1
    # donated blocks are claimed for decode replicas only (sinks attached
    # to the decode pool), and conservatively
    for e in srv.decode_pool:
        assert srv.directory.claimed(e.lb_id) <= _entry_chains(e.prefix)
    for e in srv.prefill_pool:
        assert srv.directory.claimed(e.lb_id) == set()
    # once one decode replica holds the shared prefix, later handoffs
    # rendezvous there: the prefix chains live on a single decode replica
    holders = {r for e in srv.decode_pool
               for r in [e.lb_id]
               if srv.directory.overlap(r, sys_prefix + [1], 8) >= 8}
    assert len(holders) == 1


# ------------------------------------------------------- property (hypothesis)
def test_directory_conservative_subset_property():
    """Random interleavings of cache ops on two sink-attached replicas:
    the directory's committed claims stay a conservative subset of each
    replica's retained full blocks (hence of the union of replica caches),
    and reconcile resynchronises exactly to the reachable view."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 4),
                              st.integers(1, 40)),
                    min_size=1, max_size=50),
           st.integers(4, 16), st.integers(2, 4))
    def inner(ops, num_blocks, bs):
        d = ClusterCacheDirectory()
        pcs = [PrefixCache(num_blocks, bs), PrefixCache(num_blocks, bs)]
        for i, pc in enumerate(pcs):
            pc.attach_sink(d, i)
        rng = np.random.default_rng(0)
        live = {0: {}, 1: {}}
        sid = 0
        for who, op, n in ops:
            pc = pcs[who]
            if op == 0:                      # allocate (may evict cached)
                got = pc.allocate(min(n, 4))
                if got is not None:
                    live[who][sid] = (got, [int(x) for x in
                                            rng.integers(0, 6, len(got) * bs)])
                    sid += 1
            elif op == 1 and live[who]:      # retire: insert + release
                k = next(iter(live[who]))
                blocks, toks = live[who].pop(k)
                pc.insert(toks, blocks, min(len(toks), n * bs // 2 + 1))
                pc.release(blocks)
            elif op == 2:                    # match holds refs
                prompt = [int(x) for x in rng.integers(0, 6, max(n, 2))]
                blocks, hit = pc.match(prompt)
                live[who][sid] = (blocks, prompt[:hit])
                sid += 1
            elif op == 3 and live[who]:      # plain release (no insert)
                blocks, _ = live[who].pop(next(iter(live[who])))
                pc.release(blocks)
            elif op == 4:                    # adopt (migration path)
                n_valid = min(n, 3 * bs - 1)
                seq = [int(x) for x in rng.integers(0, 6, n_valid)]
                plan = pc.adopt_blocks(seq, n_valid)
                if plan is not None:
                    blocks, _ = plan
                    pc.insert(seq, blocks, (n_valid // bs) * bs)
                    live[who][sid] = (blocks, seq)
                    sid += 1
            pc.check_invariants()
            for i, p in enumerate(pcs):      # conservative subset, always
                assert d.claimed(i) <= _entry_chains(p)
        for who in (0, 1):                   # release everything
            for blocks, _ in live[who].values():
                pcs[who].release(blocks)
        for i, p in enumerate(pcs):
            d.reconcile(i, p.reachable_chains())
            assert d.claimed(i) == p.reachable_chains()
            assert d.claimed(i) <= _entry_chains(p)

    inner()
