"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.serving.kv_cache import PagedAllocator, RowPool
from repro.serving.sampling import sample

SETTINGS = dict(max_examples=40, deadline=None)


# ------------------------------------------------------------ paged alloc
@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(1, 200), st.booleans()),
                min_size=1, max_size=40),
       st.integers(4, 64), st.integers(4, 32))
def test_paged_allocator_invariants(ops, num_blocks, block_size):
    """No block is ever owned twice; free returns everything; utilization
    and fragmentation stay in [0, 1]."""
    a = PagedAllocator(num_blocks, block_size)
    live = {}
    rid = 0
    for length, do_free in ops:
        blocks = a.allocate(rid, length)
        if blocks is not None:
            live[rid] = blocks
        owned = [b for bs in live.values() for b in bs]
        assert len(owned) == len(set(owned)), "block double-owned"
        assert 0.0 <= a.utilization() <= 1.0
        assert 0.0 <= a.internal_fragmentation() <= 1.0
        if do_free and live:
            victim = next(iter(live))
            a.free(victim)
            del live[victim]
        rid += 1
    for r in list(live):
        a.free(r)
    assert a.blocks_used() == 0
    assert len(a._free) == num_blocks


@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(1, 500), st.integers(1, 400))
def test_paged_extend_grows_monotonically(bs, l0, l1):
    # l0+l1 <= 900 <= num_blocks*bs for every bs >= 1: extend never OOMs
    a = PagedAllocator(num_blocks=1000, block_size=bs)
    a.allocate(0, l0)
    n0 = len(a.seqs[0].blocks)
    new = a.extend(0, l0 + l1)
    assert new is not None
    assert len(a.seqs[0].blocks) >= n0
    assert len(a.seqs[0].blocks) == -(-(l0 + l1) // bs)


@settings(**SETTINGS)
@given(st.integers(1, 32))
def test_row_pool_exhaustion_and_reuse(cap):
    p = RowPool(cap)
    rows = [p.allocate(i) for i in range(cap)]
    assert None not in rows and len(set(rows)) == cap
    assert p.allocate(999) is None
    p.free(rows[0])
    assert p.allocate(1000) == rows[0]


# ------------------------------------------------------------ sampling
@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.integers(2, 200))
def test_greedy_is_argmax(seed, V):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(3, V)), jnp.float32)
    toks = sample(logits, jax.random.PRNGKey(seed & 0xFFFF),
                  jnp.zeros((3,)), jnp.zeros((3,), jnp.int32), jnp.ones((3,)))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_topk_respected(seed, k):
    rng = np.random.default_rng(seed)
    V = 64
    logits = jnp.asarray(rng.normal(size=(4, V)), jnp.float32)
    toks = np.asarray(sample(
        logits, jax.random.PRNGKey(seed & 0xFFFF),
        jnp.full((4,), 1.0), jnp.full((4,), k, jnp.int32), jnp.ones((4,))))
    order = np.argsort(-np.asarray(logits), axis=-1)
    for b in range(4):
        assert toks[b] in order[b, :k]


# ------------------------------------------------------------ autoscaler
@settings(**SETTINGS)
@given(st.floats(0.1, 10.0), st.floats(0.1, 10.0), st.integers(1, 32))
def test_hpa_monotone_in_metric(m1, m2, cur):
    """Higher metric never yields fewer replicas (fresh controllers)."""
    cfg = HPAConfig(target=1.0, tolerance=0.0, max_replicas=1000,
                    stabilization_s=0.0, scale_down_cooldown_s=0.0)
    lo, hi = sorted((m1, m2))
    n_lo = Autoscaler(cfg).evaluate(0.0, cur, lo)
    n_hi = Autoscaler(cfg).evaluate(0.0, cur, hi)
    assert n_hi >= n_lo


# ------------------------------------------------------------ sharding
@settings(**SETTINGS)
@given(st.sampled_from(["tp", "zero3", "dp"]),
       st.sampled_from(["mamba2-780m", "qwen2-0.5b", "gemma3-27b",
                        "mixtral-8x7b", "qwen3-moe-30b-a3b"]))
def test_sharding_specs_well_formed(partitioning, arch):
    """Every resolved PartitionSpec uses each mesh axis at most once and
    only on divisible dims (checked without building a 256-device mesh:
    a fake mesh shape object drives the resolver)."""
    from repro.configs import get_config
    from repro.distributed.sharding import Sharder, rules_for
    from repro.models.lm import make_model
    from repro.models import params as P

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    sh = Sharder(FakeMesh(), rules_for(partitioning))
    cfg = get_config(arch)
    model = make_model(cfg)
    specs = model.param_specs()

    def check(s):
        spec = sh.spec_for(s.shape, s.axes)
        used = []
        for i, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            total = 1
            for a in axes:
                used.append(a)
                total *= FakeMesh.shape[a]
            assert s.shape[i] % total == 0, (s.shape, spec)
        assert len(used) == len(set(used)), (s.shape, s.axes, spec)

    P.tree_map_specs(check, specs)


# ------------------------------------------------------------ moe dispatch
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_moe_weight_conservation(seed):
    """Without capacity drops, per-token routed weights sum to 1 and the
    layer output is a convex combination of expert outputs."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models import params as P
    cfg = dataclasses.replace(get_config("mixtral-8x7b-smoke"),
                              capacity_factor=100.0)
    p = P.init(jax.random.PRNGKey(seed), L.moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, aux = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
