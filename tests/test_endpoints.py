"""Multi-model endpoint registry: routing by model name, scale-to-zero
cold starts (queue -> spin-up -> serve -> back to zero), priority
eviction, weighted-fair tenant scheduling, the unknown-model error DTO,
and single-endpoint equivalence with a bare orchestrator."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.autoscaler import HPAConfig
from repro.core.endpoints import EndpointRegistry, ModelEndpoint, TenantQuota
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.serving import (CompletionError, CompletionRequest, CompletionsAPI,
                           InferenceEngine, ModelsAPI, Request, SamplingParams,
                           State)
from repro.serving.scheduler import Scheduler, SchedulerConfig

ARCH = "qwen2-0.5b-smoke"


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _spec(name, cfg, **kw):
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("cold_start_steps", 0)
    return ModelEndpoint(name=name, model=cfg, **kw)


def _req(rid, cfg, rng, model=None, tenant=None, plen=8, max_new=4):
    return Request(
        rid=rid, model=model, tenant=tenant,
        prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, plen)],
        sampling=SamplingParams(max_new_tokens=max_new))


def _run(registry, t0=0.0, max_steps=400):
    t = t0
    while registry.pending() and max_steps > 0:
        registry.step(t)
        t += 1.0
        max_steps -= 1
    assert registry.pending() == 0, "registry failed to drain"
    return t


# ---------------------------------------------------------------- routing
def test_registry_routes_by_model_name(cfg, rng):
    reg = EndpointRegistry([_spec("base", cfg, seed=7),
                            _spec("draft", cfg, seed=11)])
    assert reg.names() == ["base", "draft"]
    r1 = _req(0, cfg, rng, model="draft")
    r2 = _req(1, cfg, rng, model="base")
    r3 = _req(2, cfg, rng, model="base")
    for r in (r1, r2, r3):
        assert reg.submit(r, now=0.0)
    assert reg.resolve("draft").pending() == 1
    assert reg.resolve("base").pending() == 2
    # tenant label hygiene: unset tenants land in "default"
    assert r1.tenant == "default"
    done = reg.run(max_steps=300, now=1.0)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.output) == 4 for r in done)
    c = reg.metrics.get("endpoint_requests_total")
    assert c.value(endpoint="base", tenant="default") == 2
    assert c.value(endpoint="draft", tenant="default") == 1

    with pytest.raises(KeyError):
        reg.submit(_req(9, cfg, rng, model="nope"), now=0.0)


# ---------------------------------------------------------- scale-to-zero
def test_scale_to_zero_cold_start_and_teardown(cfg, rng):
    reg = EndpointRegistry([_spec(
        "z", cfg, min_replicas=0, cold_start_steps=3,
        idle_ticks_to_zero=2, control_every_steps=2)])
    assert reg.state("z") == "scaled_to_zero"
    assert reg.resolve("z").engines == []

    # first request wakes the endpoint: it queues behind the warm-up
    # instead of rejecting, and its TTFT pays for the cold start
    req = _req(0, cfg, rng, model="z")
    assert reg.submit(req, now=0.0)
    assert reg.state("z") == "cold"
    assert len(reg.resolve("z").engines) == 1
    assert req.state is State.QUEUED

    t = _run(reg, t0=0.0)
    assert req.state is State.DONE and len(req.output) == 4
    assert req.ttft is not None and req.ttft >= 3.0

    m = reg.metrics
    assert m.get("endpoint_cold_starts_total").value(endpoint="z") == 1
    assert m.get("endpoint_cold_start_steps").value(endpoint="z") == 3
    assert m.get("endpoint_cold_start_seconds").value(endpoint="z") > 0
    # the cold start is a closed trace span
    cold = [s for tr in reg.tracer.traces() for s in tr.spans
            if s.name == "cold_start"]
    assert len(cold) == 1 and cold[0].t1 is not None

    # idle teardown: after idle_ticks_to_zero quiet control ticks the
    # endpoint scales back to zero
    for _ in range(12):
        reg.step(t)
        t += 1.0
    assert reg.state("z") == "scaled_to_zero"
    assert reg.resolve("z").engines == []

    # and the next request cold-starts again
    req2 = _req(1, cfg, rng, model="z")
    assert reg.submit(req2, now=t)
    _run(reg, t0=t)
    assert req2.state is State.DONE
    assert m.get("endpoint_cold_starts_total").value(endpoint="z") == 2


# ------------------------------------------------------- priority eviction
def test_priority_eviction_frees_capacity(cfg, rng):
    reg = EndpointRegistry(
        [_spec("low", cfg, priority=0, min_replicas=1, seed=7),
         _spec("high", cfg, priority=1, min_replicas=0, cold_start_steps=1,
               seed=11)],
        cluster_max_replicas=1)
    assert reg.total_replicas() == 1

    # the high-priority endpoint's wakeup evicts low's idle replica
    req = _req(0, cfg, rng, model="high")
    assert reg.submit(req, now=0.0)
    assert reg.resolve("low").engines == []
    assert len(reg.resolve("high").engines) == 1
    assert reg.state("low") == "scaled_to_zero" or not reg.resolve("low").engines
    assert reg.metrics.get("endpoint_evictions_total").value(
        victim="low", claimant="high") == 1
    _run(reg)
    assert req.state is State.DONE and len(req.output) == 4

    # the reverse never happens: low cannot evict high, so its wakeup is
    # rejected for capacity (priority strictly lower than any victim's)
    req2 = _req(1, cfg, rng, model="low")
    assert not reg.submit(req2, now=50.0)
    assert req2.state is State.REJECTED
    assert reg.metrics.get("tenant_rejections_total").value(
        tenant="default", reason="capacity") == 1
    assert len(reg.resolve("high").engines) == 1


# ------------------------------------------------------------ weighted fair
def test_wfq_scheduler_token_shares_follow_weights():
    sched = Scheduler(SchedulerConfig(
        policy="wfq", tenant_weights={"a": 3.0, "b": 1.0},
        max_prefill_per_step=4))
    for i in range(40):
        for tenant in ("a", "b"):
            r = Request(rid=len(sched.queue), prompt=[1] * 8, tenant=tenant,
                        sampling=SamplingParams(max_new_tokens=4))
            sched.submit(r, now=0.0)
    admitted = {"a": 0, "b": 0}
    # drain half the backlog: under saturation the admitted token shares
    # must track the 3:1 weights
    for step in range(10):
        for r in sched.next_batch(free_slots=4, now=float(step)):
            admitted[r.tenant] += len(r.prompt) + r.sampling.max_new_tokens
    assert admitted["a"] + admitted["b"] == 40 * 12
    ratio = admitted["a"] / admitted["b"]
    assert 2.0 <= ratio <= 4.0, ratio
    # FIFO within a tenant is preserved and both tenants drain eventually
    while sched.queue:
        sched.next_batch(free_slots=8, now=100.0)
    assert sched.depth() == 0


def test_wfq_new_tenant_joins_at_min_vtime_no_banked_credit():
    sched = Scheduler(SchedulerConfig(policy="wfq", max_prefill_per_step=2))
    for i in range(8):
        sched.submit(Request(rid=i, prompt=[1] * 8, tenant="a",
                             sampling=SamplingParams(max_new_tokens=4)),
                     now=0.0)
    for _ in range(3):
        sched.next_batch(free_slots=2, now=1.0)
    # "b" arrives late: it must not monopolize admission with credit
    # banked while idle — picks alternate rather than all-b
    for i in range(8):
        sched.submit(Request(rid=100 + i, prompt=[1] * 8, tenant="b",
                             sampling=SamplingParams(max_new_tokens=4)),
                     now=2.0)
    batch = sched.next_batch(free_slots=4, now=2.0)
    tenants = [r.tenant for r in batch]
    assert "a" in tenants and "b" in tenants


def test_wfq_tenant_ttft_tracks_weight_under_saturation(cfg, rng):
    reg = EndpointRegistry(
        [_spec("m", cfg, capacity=2,
               sched=SchedulerConfig(policy="wfq", max_prefill_per_step=2,
                                     tenant_weights={"gold": 4.0,
                                                     "free": 1.0}))],
        tenants={"gold": TenantQuota(weight=4.0),
                 "free": TenantQuota(weight=1.0)})
    reqs = []
    for i in range(8):
        for tenant in ("gold", "free"):
            r = _req(len(reqs), cfg, rng, model="m", tenant=tenant, plen=8,
                     max_new=3)
            reqs.append(r)
            assert reg.submit(r, now=0.0)
    _run(reg)
    by = {"gold": [], "free": []}
    for r in reqs:
        assert r.state is State.DONE
        by[r.tenant].append(r.ttft)
    # saturating trace on one capacity-2 replica: the weight-4 tenant's
    # requests get admitted ahead of the weight-1 tenant's backlog
    assert np.mean(by["gold"]) < np.mean(by["free"])


# ----------------------------------------------------------- tenant quotas
def test_tenant_quota_rejects_over_inflight(cfg, rng):
    reg = EndpointRegistry(
        [_spec("m", cfg)],
        tenants={"capped": TenantQuota(max_inflight=2)})
    r1 = _req(0, cfg, rng, model="m", tenant="capped")
    r2 = _req(1, cfg, rng, model="m", tenant="capped")
    r3 = _req(2, cfg, rng, model="m", tenant="capped")
    assert reg.submit(r1, now=0.0) and reg.submit(r2, now=0.0)
    assert not reg.submit(r3, now=0.0)
    assert r3.state is State.REJECTED
    assert reg.metrics.get("tenant_rejections_total").value(
        tenant="capped", reason="quota") == 1
    t = _run(reg)
    # quota releases as requests finish
    r4 = _req(3, cfg, rng, model="m", tenant="capped")
    assert reg.submit(r4, now=t)
    _run(reg, t0=t)
    assert r4.state is State.DONE


# ----------------------------------------------------- unknown-model errors
def test_unknown_model_returns_error_dto(cfg, rng):
    reg = EndpointRegistry([_spec("real", cfg)])
    api = CompletionsAPI(reg)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 6)]

    resp = api.create(CompletionRequest(prompt=prompt, model="ghost"),
                      now=0.0)
    assert isinstance(resp, CompletionError)
    assert resp.type == "invalid_request_error"
    assert resp.param == "model" and resp.code == "model_not_found"
    d = resp.to_dict()
    assert d["error"]["type"] == "invalid_request_error"
    assert "ghost" in d["error"]["message"]
    assert resp.to_sse().startswith("data: ")

    frames = list(api.stream(CompletionRequest(prompt=prompt, model="ghost",
                                               stream=True), now=0.0))
    assert len(frames) == 1 and isinstance(frames[0], CompletionError)
    # nothing was admitted anywhere
    assert reg.pending() == 0

    # a routable model serves normally and the response echoes the
    # endpoint name
    ok = api.create(CompletionRequest(prompt=prompt, model="real",
                                      max_tokens=3), now=0.0)
    assert not isinstance(ok, CompletionError)
    assert ok.model == "real"
    assert len(ok.choices[0].tokens) == 3

    # single-model backends reject mismatches the same way
    eng_api = CompletionsAPI(InferenceEngine(cfg, capacity=2, max_len=64,
                                             buckets=(8, 16)), model="solo")
    bad = eng_api.create(CompletionRequest(prompt=prompt, model="other"),
                         now=0.0)
    assert isinstance(bad, CompletionError)


def test_models_api_lists_endpoint_states(cfg):
    reg = EndpointRegistry([_spec("warm", cfg),
                            _spec("zero", cfg, min_replicas=0)])
    api = ModelsAPI(reg)
    listing = api.list()
    assert listing.object == "list"
    byid = {m.id: m for m in listing.data}
    assert byid["warm"].state == "ready" and byid["warm"].replicas == 1
    assert byid["zero"].state == "scaled_to_zero"
    assert byid["zero"].replicas == 0
    one = api.retrieve("warm")
    assert one.object == "model" and one.priority == 0
    missing = api.retrieve("ghost")
    assert isinstance(missing, CompletionError)
    assert missing.code == "model_not_found"


# ------------------------------------------------- wrapper equivalence
def test_single_endpoint_registry_matches_bare_orchestrator(cfg, rng):
    """One-endpoint registry == pre-registry orchestrator, token for
    token: same engines, same clock, same control cadence."""
    hpa = HPAConfig(metric="queue", target=4.0, max_replicas=2,
                    stabilization_s=5.0, scale_down_cooldown_s=5.0)

    def make():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               seed=7)

    orch = Orchestrator(make, OrchestratorConfig(
        hpa=hpa, max_replicas=2, cold_start_steps=0))
    reg = EndpointRegistry([ModelEndpoint(
        name="solo", make_engine=make, hpa=hpa, max_replicas=2,
        cold_start_steps=0)])

    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, 6 + i % 5)]
               for i in range(8)]
    for i, p in enumerate(prompts):
        orch.submit(Request(rid=i, prompt=list(p),
                            sampling=SamplingParams(max_new_tokens=4)),
                    now=0.0)
        reg.submit(Request(rid=i, prompt=list(p), model="solo",
                           sampling=SamplingParams(max_new_tokens=4)),
                   now=0.0)
    t = 0.0
    while (orch.pending() or reg.pending()) and t < 300:
        if orch.pending():
            orch.step(t)
        if reg.pending():
            reg.step(t)
        t += 1.0
    a = {r.rid: r.output for r in orch.run(max_steps=0)}
    b = {r.rid: r.output for r in reg.finished()}
    assert set(a) == set(b) == set(range(8))
    assert a == b


# ------------------------------------------------------- tenant stamping
def test_bare_orchestrator_stamps_default_tenant(cfg, rng):
    orch = Orchestrator(
        lambda: InferenceEngine(cfg, capacity=2, max_len=64, buckets=(8, 16)),
        OrchestratorConfig(cold_start_steps=0))
    r = _req(0, cfg, rng)
    assert r.tenant is None
    orch.submit(r, now=0.0)
    assert r.tenant == "default"
