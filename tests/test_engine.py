"""Integration tests: continuous-batching engine, migration, microservice
pipeline, orchestrator — real JAX models on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as P
from repro.models.lm import make_model
from repro.serving import InferenceEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerConfig

ARCH = "qwen2-0.5b-smoke"


def _mk_engine(**kw):
    cfg = get_config(ARCH)
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    return cfg, InferenceEngine(cfg, **kw)


def _reqs(cfg, n, rng, max_new=5, lo=4, hi=14):
    out = []
    for i in range(n):
        out.append(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(lo, hi)))],
            sampling=SamplingParams(max_new_tokens=max_new)))
    return out


def test_engine_serves_all_requests(rng):
    cfg, eng = _mk_engine()
    for r in _reqs(cfg, 6, rng):
        eng.submit(r)
    done = eng.run(max_steps=300)
    assert len(done) == 6
    for r in done:
        assert len(r.output) == 5
        assert r.ttft is not None and r.e2e is not None


def test_engine_greedy_matches_direct_decode(rng):
    """Engine output (greedy, bucketed prefill) == straight-line decode."""
    cfg, eng = _mk_engine()
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 11)]
    req = Request(rid=0, prompt=prompt,
                  sampling=SamplingParams(max_new_tokens=6, temperature=0.0))
    eng.submit(req)
    done = eng.run(max_steps=60)
    got = done[0].output

    model = make_model(cfg)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 64))(
        eng.params, {"tokens": toks})
    exp = [int(jnp.argmax(logits, -1)[0])]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    cur = jnp.asarray([[exp[-1]]], jnp.int32)
    for _ in range(5):
        logits, cache = jax.jit(model.decode_step)(eng.params, cur, pos, cache)
        exp.append(int(jnp.argmax(logits, -1)[0]))
        cur = jnp.asarray([[exp[-1]]], jnp.int32)
        pos = pos + 1
    assert got == exp, (got, exp)


@pytest.mark.slow
def test_engine_bucketed_prefill_exactness(rng):
    """Same prompt served via different bucket sizes gives identical greedy
    output (right-padding correctness: ring caches, logits gather)."""
    cfg = get_config("gemma3-27b-smoke")   # has ring (local) layers
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
    outs = []
    for buckets in [(16,), (32,)]:
        eng = InferenceEngine(cfg, capacity=2, max_len=64, buckets=buckets, seed=5)
        eng.submit(Request(rid=0, prompt=prompt,
                           sampling=SamplingParams(max_new_tokens=5)))
        done = eng.run(max_steps=40)
        outs.append(done[0].output)
    assert outs[0] == outs[1], outs


@pytest.mark.slow
def test_engine_ssm_bucketed_prefill(rng):
    """SSM state must be exact under right-padded prefill."""
    cfg = get_config("mamba2-780m-smoke")
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 10)]
    outs = []
    for buckets in [(16,), (32,)]:
        eng = InferenceEngine(cfg, capacity=2, max_len=64, buckets=buckets, seed=5)
        eng.submit(Request(rid=0, prompt=prompt,
                           sampling=SamplingParams(max_new_tokens=5)))
        outs.append(eng.run(max_steps=40)[0].output)
    assert outs[0] == outs[1], outs


def test_migration_preserves_generation(rng):
    """Llumnix-style handoff: migrating mid-generation must not change the
    greedy continuation."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk_engine(seed=3)
    _, eng_b = _mk_engine(seed=3)
    eng_b.params = eng_a.params            # same replica weights

    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
    # reference: run fully on A
    ref_eng = _mk_engine(seed=3)[1]
    ref_eng.params = eng_a.params
    ref_eng.submit(Request(rid=0, prompt=list(prompt),
                           sampling=SamplingParams(max_new_tokens=8)))
    ref = ref_eng.run(max_steps=60)[0].output

    req = Request(rid=0, prompt=list(prompt),
                  sampling=SamplingParams(max_new_tokens=8))
    eng_a.submit(req)
    for _ in range(4):                     # prefill + a few decode steps
        eng_a.step()
    assert req.state.name == "DECODE" and len(req.output) >= 2
    mgr = MigrationManager()
    ev = mgr.migrate(eng_a, eng_b, rid=0, now=0.0)
    assert ev is not None and ev.bytes > 0
    done = eng_b.run(max_steps=60)
    assert done[0].output == ref
    assert done[0].migrations == 1


def test_migration_at_chunk_boundary_preserves_generation(rng):
    """A mid-chunked-prefill request migrated at a chunk boundary resumes
    its remaining prompt on the destination (the payload carries prefill
    progress — no truncation into a bogus decode) and produces greedy
    output identical to an unmigrated run."""
    from repro.core.migration import MigrationManager
    cfg, eng_a = _mk_engine(seed=3, max_len=96)
    _, eng_b = _mk_engine(seed=3, max_len=96)
    eng_b.params = eng_a.params
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 40)]  # chunked
    ref_eng = _mk_engine(seed=3, max_len=96)[1]
    ref_eng.params = eng_a.params
    ref_eng.submit(Request(rid=0, prompt=list(prompt),
                           sampling=SamplingParams(max_new_tokens=6)))
    ref = ref_eng.run(max_steps=100)[0].output

    req = Request(rid=0, prompt=list(prompt),
                  sampling=SamplingParams(max_new_tokens=6))
    eng_a.submit(req)
    eng_a.step()                              # first chunk only
    assert req.state.name == "PREFILL" and len(req.output) == 0
    mgr = MigrationManager()
    rid = mgr.pick_request(eng_a)
    assert rid == 0                           # mid-prefill rows are candidates
    ev = mgr.migrate(eng_a, eng_b, rid, now=0.0)
    assert ev is not None and ev.phase == "prefill"
    done = eng_b.run(max_steps=100)
    assert done[0].output == ref
    assert done[0].migrations == 1
    # restricting to completed-prefill candidates is still available
    assert mgr.pick_request(eng_a, include_prefill=False) is None


def test_staged_pipeline_matches_monolithic(rng):
    """Microservice decomposition: stage-partitioned decode == monolithic."""
    from repro.core.microservice import StagePipeline
    cfg = get_config(ARCH)
    model = make_model(cfg)
    params = P.init(jax.random.PRNGKey(0), model.param_specs())
    B, S, MAX = 2, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size).astype(jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
        params, {"tokens": toks})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    ref_logits, _ = jax.jit(model.decode_step)(params, nxt, pos, cache)

    for num_stages in (2, 4):
        pipe = StagePipeline(model, params, num_stages=num_stages)
        got_logits, _ = pipe.decode_step(nxt, pos, cache)
        np.testing.assert_allclose(np.asarray(got_logits, np.float32),
                                   np.asarray(ref_logits, np.float32),
                                   atol=1e-3)
        # profiler saw every stage
        for s in range(pipe.staged.num_stages):
            assert pipe.profiler.latency[f"stage/{s}"].count() == 1


def test_staged_pipeline_split_replicas(rng):
    from repro.core.microservice import StagePipeline
    cfg = get_config(ARCH)
    model = make_model(cfg)
    params = P.init(jax.random.PRNGKey(0), model.param_specs())
    B, S, MAX = 4, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size).astype(jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
        params, {"tokens": toks})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    ref_logits, _ = jax.jit(model.decode_step)(params, nxt, pos, cache)

    pipe = StagePipeline(model, params, num_stages=2)
    pipe.scale_stage(0, 2, now=0.0)        # bottleneck stage gets 2 replicas
    got, _ = pipe.decode_step(nxt, pos, cache)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_logits, np.float32), atol=1e-3)


def test_orchestrator_scales_and_serves(rng):
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.core.autoscaler import HPAConfig
    cfg = get_config(ARCH)

    def make_engine():
        return InferenceEngine(cfg, capacity=2, max_len=48, buckets=(8, 16),
                               seed=11,
                               sched=SchedulerConfig(max_prefill_per_step=1))

    orch = Orchestrator(make_engine, OrchestratorConfig(
        min_replicas=1, hpa=HPAConfig(metric="queue", target=2.0,
                                      max_replicas=3, tolerance=0.0,
                                      stabilization_s=0.0,
                                      scale_down_cooldown_s=1e9),
        control_every_steps=2))
    reqs = _reqs(cfg, 10, rng, max_new=4)
    for r in reqs:
        orch.submit(r)
    done = orch.run(max_steps=400)
    assert len(done) == 10
    assert len(orch.engines) > 1, "queue pressure should have scaled up"
    assert all(len(r.output) == 4 for r in done)


def test_engine_serves_encoder_decoder(rng):
    """whisper-style enc-dec through the engine (frames via extras)."""
    import numpy as np
    cfg = get_config("whisper-small-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=48, buckets=(8, 16), seed=9)
    frames = np.asarray(rng.normal(0, 0.02, (1, cfg.encoder_seq, cfg.d_model)),
                        np.float32)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 6)],
            sampling=SamplingParams(max_new_tokens=4),
            extras={"frames": frames}))
    done = eng.run(max_steps=120)
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)


def test_engine_serves_vlm(rng):
    import numpy as np
    cfg = get_config("paligemma-3b-smoke")
    eng = InferenceEngine(cfg, capacity=2, max_len=48, buckets=(8,), seed=9)
    patches = np.asarray(rng.normal(0, 0.02, (1, cfg.num_vision_tokens,
                                              cfg.d_model)), np.float32)
    eng.submit(Request(rid=0,
                       prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 7)],
                       sampling=SamplingParams(max_new_tokens=4),
                       extras={"patches": patches}))
    done = eng.run(max_steps=60)
    assert len(done) == 1 and len(done[0].output) == 4


def test_disaggregated_prefill_decode(rng):
    """DistServe-style PD disaggregation: outputs match monolithic serving
    and decode engines never execute a prefill."""
    from repro.core.disaggregation import DisaggConfig, DisaggregatedServer
    cfg = get_config(ARCH)

    def mk():
        return InferenceEngine(cfg, capacity=4, max_len=64, buckets=(8, 16),
                               seed=21)

    # monolithic reference
    ref_eng = mk()
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
               for _ in range(4)]
    for i, p in enumerate(prompts):
        ref_eng.submit(Request(rid=i, prompt=list(p),
                               sampling=SamplingParams(max_new_tokens=6)))
    ref = {r.rid: r.output for r in ref_eng.run(max_steps=100)}

    srv = DisaggregatedServer(mk, DisaggConfig(prefill_engines=1,
                                               decode_engines=2))
    srv.prefill_pool[0].params = ref_eng.params
    for e in srv.decode_pool:
        e.params = ref_eng.params
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=list(p),
                           sampling=SamplingParams(max_new_tokens=6)))
    done = srv.run(max_steps=200)
    assert len(done) == 4
    assert {r.rid: r.output for r in done} == ref
    # decode engines never compiled a prefill program
    for de in srv.decode_pool:
        assert not de._prefill, "decode engine ran a prefill"
    assert all(r.migrations == 1 for r in done)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b-smoke", "mixtral-8x7b-smoke",
                                  "gemma-2b-smoke", "qwen3-moe-30b-a3b-smoke"])
def test_engine_serves_all_families(arch, rng):
    """Hybrid / MoE / MQA families through the continuous-batching engine."""
    cfg = get_config(arch)
    eng = InferenceEngine(cfg, capacity=2, max_len=64, buckets=(16,), seed=13)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 8)],
            sampling=SamplingParams(max_new_tokens=4)))
    done = eng.run(max_steps=150)
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)


def test_stage_profiler_drives_hpa(rng):
    """Glue check for the paper's full loop on the real stage pipeline:
    profiler ranks stage latencies -> HPA law computes the replica count for
    the measured bottleneck stage -> the pipeline scales that stage."""
    from repro.core.autoscaler import Autoscaler, HPAConfig
    from repro.core.microservice import StagePipeline
    cfg = get_config(ARCH)
    model = make_model(cfg)
    params = P.init(jax.random.PRNGKey(0), model.param_specs())
    B, S, MAX = 2, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size).astype(jnp.int32)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(
        params, {"tokens": toks})
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)

    pipe = StagePipeline(model, params, num_stages=2)
    for i in range(3):                       # profile a few decode steps
        logits, cache = pipe.decode_step(nxt, pos, cache, now=float(i))
    ranked = pipe.profiler.bottlenecks("stage/")
    assert len(ranked) == 2 and ranked[0][1] >= ranked[1][1]
    hot = int(ranked[0][0].split("/")[1])

    hpa = Autoscaler(HPAConfig(metric="latency", target=ranked[0][1] / 2,
                               tolerance=0.0, max_replicas=4))
    new = hpa.evaluate(3.0, 1, ranked[0][1])
    assert new >= 2
    pipe.scale_stage(hot, new, now=3.0)
    assert len(pipe.replicas[hot]) == new
    # pipeline still numerically consistent after scaling
    logits2, _ = pipe.decode_step(nxt, pos, cache, now=4.0)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
