"""Unit tests for the static HLO analyzer (launch/hlo.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo as H


def _module_for(fn, *args):
    return H.HloModule(jax.jit(fn).lower(*args).compile().as_text())


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    mod = _module_for(lambda a, b: a @ b, a, b)
    assert mod.flops() == pytest.approx(2 * 64 * 128 * 32)


def test_while_trip_multiplier():
    a = jnp.zeros((32, 32), jnp.float32)

    def loop(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    mod = _module_for(loop, a)
    # 7 iterations x (2 * 32^3)
    assert mod.flops() == pytest.approx(7 * 2 * 32**3, rel=0.01)


def test_bytes_in_place_dus():
    buf = jnp.zeros((128, 1024), jnp.float32)
    upd = jnp.ones((1, 1024), jnp.float32)

    def f(buf, upd, i):
        return jax.lax.dynamic_update_slice(buf, upd, (i, 0))

    # donated => aliased in-place update, no defensive copy
    comp = jax.jit(f, donate_argnums=(0,)).lower(
        buf, upd, jnp.asarray(3)).compile()
    mod = H.HloModule(comp.as_text())
    # in-place: ~2x the update slice, NOT the 512 KiB buffer
    assert mod.bytes_accessed() < 10 * upd.nbytes


def test_collective_wire_factors():
    txt = """HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main () -> f32[] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups=[16,16]<=[256]T(1,0), to_apply=%add
  %ag = f32[4096]{0} all-gather(%ar), replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  ROOT %r = f32[] constant(0)
}
"""
    mod = H.HloModule(txt)
    c = mod.collectives()
    assert c["all-reduce"] == pytest.approx(2 * 4096 * 15 / 16)
    assert c["all-gather"] == pytest.approx(4096 * 4 * 15 / 16)


def test_parse_tuple_shapes_and_params():
    line = "  %w = (s32[], bf16[4,8]{1,0}) while(%t), condition=%c, body=%b"
    op = H._parse_op(line)
    assert op.op == "while"
    assert op.operands == ["t"]
    assert H._shape_bytes(op.out_tokens) == 4 + 4 * 8 * 2


def test_memory_per_device_fields():
    f = jax.jit(lambda x: x * 2.0)
    comp = f.lower(jax.ShapeDtypeStruct((256,), jnp.float32)).compile()
    mem = H.memory_per_device(comp)
    assert mem["peak_bytes"] >= 0
    assert mem["argument_bytes"] == 1024
