"""Unit tests for the paper's six control-plane modules."""
import math

import pytest

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.core.loadbalancer import LoadBalancer
from repro.core.migration import MigrationConfig, MigrationManager
from repro.core.predictor import EWMA, HoltWinters, WindowedAR
from repro.core.profiler import Profiler, SeriesWindow


# ------------------------------------------------------------ autoscaler
def test_hpa_control_law_exact():
    """desired = ceil(current * metric / target) — the K8s formula."""
    a = Autoscaler(HPAConfig(metric="util", target=0.5, max_replicas=100,
                             tolerance=0.0, stabilization_s=0.0,
                             scale_down_cooldown_s=0.0))
    assert a.evaluate(0.0, 2, 1.0) == math.ceil(2 * 1.0 / 0.5)
    assert a.evaluate(1.0, 4, 0.25) == 2
    assert a.evaluate(2.0, 3, 0.5) == 3      # ratio 1 => no change


def test_hpa_tolerance_band():
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.1))
    assert a.evaluate(0.0, 4, 1.05) == 4     # within +-10%
    assert a.evaluate(1.0, 4, 1.3) > 4


def test_hpa_min_max_clamp():
    a = Autoscaler(HPAConfig(target=1.0, min_replicas=2, max_replicas=5,
                             tolerance=0.0))
    assert a.evaluate(0.0, 3, 100.0) == 5
    a2 = Autoscaler(HPAConfig(target=1.0, min_replicas=2, max_replicas=5,
                              tolerance=0.0, stabilization_s=0.0,
                              scale_down_cooldown_s=0.0))
    assert a2.evaluate(0.0, 3, 0.01) == 2


def test_hpa_scale_down_stabilization():
    cfg = HPAConfig(target=1.0, tolerance=0.0, stabilization_s=30.0,
                    scale_down_cooldown_s=0.0, max_replicas=10)
    a = Autoscaler(cfg)
    assert a.evaluate(0.0, 4, 2.0) == 8          # scale up immediately
    # low metric right after: stabilization window still remembers desired=8
    assert a.evaluate(1.0, 8, 0.1) == 8
    # 31s later the high sample left the window -> scale down allowed
    assert a.evaluate(32.0, 8, 0.1) < 8


def test_hpa_proactive_uses_forecast():
    pred = HoltWinters(dt=1.0)
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.0, proactive=True,
                             horizon_s=5.0, max_replicas=64), predictor=pred)
    n = 1
    for t in range(10):                      # rising load 1,2,...,10
        n = a.evaluate(float(t), n, float(t + 1))
    # forecast(5s ahead) > last observation => scaled beyond reactive value
    assert n >= 10


# ------------------------------------------------------------ predictor
def test_predictors_track_trend():
    for p in (EWMA(0.5), HoltWinters(), WindowedAR(order=2, window=32)):
        for t in range(50):
            p.observe(float(t), 2.0 * t)
        f = p.forecast(1.0)
        assert f > 60.0, type(p).__name__


def test_ar_flat_series():
    p = WindowedAR(order=3, window=16)
    for t in range(20):
        p.observe(float(t), 5.0)
    assert abs(p.forecast() - 5.0) < 0.5


# ------------------------------------------------------------ balancer
class _R:
    def __init__(self, load):
        self._l = load


def test_lb_least_outstanding():
    lb = LoadBalancer("least")
    rs = [_R(5), _R(1), _R(3)]
    assert lb.pick(rs, load=lambda r: r._l) is rs[1]


def test_lb_round_robin_cycles():
    lb = LoadBalancer("rr")
    rs = [_R(0), _R(0), _R(0)]
    picks = [lb.pick(rs, load=lambda r: 0) for _ in range(6)]
    assert len(set(map(id, picks))) == 3
    # unbiased: replica 0 gets the very first pick, strict rotation after
    assert [rs.index(p) for p in picks] == [0, 1, 2, 0, 1, 2]


def test_lb_round_robin_unbiased_after_resize():
    """Shrinking the replica set must not skip anyone on the next pick."""
    lb = LoadBalancer("rr")
    rs = [_R(0) for _ in range(3)]
    for _ in range(4):                       # counter now mid-rotation (1)
        lb.pick(rs, load=lambda r: 0)
    small = rs[:2]
    picks = [small.index(lb.pick(small, load=lambda r: 0)) for _ in range(4)]
    assert sorted(picks[:2]) == [0, 1] and sorted(picks[2:]) == [0, 1]


def test_lb_prefix_affinity_sticky_and_load_guarded():
    lb = LoadBalancer("prefix", affinity_slack=2.0)
    rs = [_R(0), _R(0), _R(0)]
    key = (1, 2, 3)
    first = lb.pick(rs, load=lambda r: r._l, affinity_key=key)
    # same key -> same replica, regardless of other keys routed in between
    lb.pick(rs, load=lambda r: r._l, affinity_key=(9, 9))
    assert lb.pick(rs, load=lambda r: r._l, affinity_key=key) is first
    # overload spill: the affine replica beyond the slack loses the pick
    first._l = 10.0
    spilled = lb.pick(rs, load=lambda r: r._l, affinity_key=key)
    assert spilled is not first
    # ...and recovers stickiness once drained
    first._l = 0.0
    assert lb.pick(rs, load=lambda r: r._l, affinity_key=key) is first


def test_lb_p2c_prefers_lower_load():
    lb = LoadBalancer("p2c", seed=1)
    rs = [_R(100), _R(0)]
    wins = sum(lb.pick(rs, load=lambda r: r._l) is rs[1] for _ in range(50))
    assert wins == 50                        # of any sampled pair, lower wins


# ------------------------------------------------------------ profiler
def test_profiler_window_and_percentiles():
    w = SeriesWindow(window_s=10.0)
    for i in range(100):
        w.observe(float(i) * 0.1, float(i))
    vals = w.values(now=9.9)
    assert min(vals) >= 0.0 and w.percentile(50, now=9.9) > 0


def test_profiler_bottleneck_ranking():
    p = Profiler()
    p.observe_latency("layer/27", 1.0, 10.0)
    p.observe_latency("layer/30", 1.0, 0.05)
    p.observe_latency("layer/1", 1.0, 0.06)
    top = p.bottlenecks("layer/")
    assert top[0][0] == "layer/27"
    assert p.hotspot_ratio("layer/") == pytest.approx(200.0)


def test_profiler_right_skew_detection():
    p = Profiler()
    for i in range(50):
        p.observe_latency("x", 1.0, 0.1)
    for _ in range(3):
        p.observe_latency("x", 1.0, 5.0)     # heavy right tail
    assert p.right_skewed("x", now=1.0)


# ------------------------------------------------------------ migration
def test_migration_plan_balances():
    m = MigrationManager(MigrationConfig(imbalance_threshold=0.3))
    moves = m.plan([0.9, 0.1, 0.5])
    assert moves and moves[0] == (0, 1)


def test_migration_plan_noop_when_balanced():
    m = MigrationManager(MigrationConfig(imbalance_threshold=0.3))
    assert m.plan([0.5, 0.45, 0.55]) == []


def test_migration_drains_straggler():
    m = MigrationManager(MigrationConfig(straggler_speed=0.6))
    moves = m.plan([0.2, 0.3], speeds=[0.5, 1.0])
    assert moves and moves[0][0] == 0


def test_transfer_time_cost_model():
    m = MigrationManager(MigrationConfig(bandwidth_Bps=1e9, overhead_s=0.01))
    assert m.transfer_time(1e9) == pytest.approx(1.01)
