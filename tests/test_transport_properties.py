"""Property test (hypothesis-guarded like test_prefix_cache.py): the
cluster cache directory, fed over the simulated transport, stays a
conservative subset of replica state under random drop/reorder/duplicate
schedules, and anti-entropy restores exact agreement once it quiesces."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cache_directory import ClusterCacheDirectory
from repro.core.transport import (DirectoryTransportClient,
                                  DirectoryTransportService, FaultSpec,
                                  LinkSpec, Transport)

SETTINGS = dict(max_examples=30, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 1),
                          st.integers(0, 9)), min_size=1, max_size=60),
       st.floats(0.0, 0.9), st.floats(0.0, 0.9), st.floats(0.0, 0.9),
       st.integers(0, 100))
def test_directory_conservative_subset_under_random_faults(
        ops, p_drop, p_reorder, p_dup, seed):
    """Random insert/evict/reconcile schedules from two replicas over a
    lossy, reordering, duplicating link: (a) the directory never claims a
    chain the replica never inserted (no corruption, no cross-replica
    leakage), and (b) once the faults clear and one reconcile round
    quiesces, the claimed sets equal the replica truth exactly — the
    anti-entropy repair the conservative-subset invariant rests on."""
    directory = ClusterCacheDirectory()
    tp = Transport(LinkSpec(latency_steps=1, bandwidth=float("inf"),
                            max_in_flight=10_000),
                   FaultSpec(drop=p_drop, reorder=p_reorder,
                             duplicate=p_dup, seed=seed))
    DirectoryTransportService(directory).bind(tp, "ctrl")
    clients = {r: DirectoryTransportClient(tp, f"r{r}", "ctrl")
               for r in (0, 1)}
    truth = {0: set(), 1: set()}
    ever = {0: set(), 1: set()}
    for op, r, c in ops:
        if op == 0:
            truth[r].add(c)
            ever[r].add(c)
            clients[r].on_insert(r, c)
        elif op == 1 and c in truth[r]:
            truth[r].discard(c)
            clients[r].on_evict(r, c)
        else:
            clients[r].reconcile(r, truth[r])
        tp.step()
        for rr in (0, 1):
            assert directory.claimed(rr) <= ever[rr], \
                "the directory claimed a chain this replica never inserted"
    tp.faults = FaultSpec()              # quiesce: clean final anti-entropy
    for r in (0, 1):
        clients[r].reconcile(r, truth[r])
    tp.quiesce()
    for r in (0, 1):
        assert directory.claimed(r) == truth[r], \
            (r, directory.claimed(r) ^ truth[r])
