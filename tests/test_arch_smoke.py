"""Per-architecture smoke tests: reduced config, one train + prefill/decode
step on CPU, asserting shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import params as P
from repro.models.lm import make_model
from repro.training.optimizer import init_opt_state
from repro.training.steps import make_train_step

pytestmark = pytest.mark.slow  # per-arch train/prefill/decode over the full zoo

B, S, MAX = 2, 32, 48


def _batch(cfg, key, with_labels=True):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = toks
    if cfg.num_vision_tokens:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_vision_tokens, cfg.d_model)) * 0.02
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_config(arch + "-smoke")
    model, step = make_train_step(cfg)
    specs = model.param_specs()
    params = P.init(jax.random.PRNGKey(0), specs)
    opt = init_opt_state(specs)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    params2, opt2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert int(opt2["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(params2)[0]
    assert l0.shape == jax.tree.leaves(P.abstract(specs))[0].shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch + "-smoke")
    model = make_model(cfg)
    params = P.init(jax.random.PRNGKey(0), model.param_specs())
    batch = _batch(cfg, jax.random.PRNGKey(2), with_labels=False)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S + (cfg.num_vision_tokens or 0), jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, nxt, pos, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m", "gemma3-27b",
                                  "mixtral-8x7b", "whisper-small",
                                  "paligemma-3b"])
def test_decode_matches_prefill(arch):
    """Token S decoded with the prefill cache must match running prefill on
    S+1 tokens (MoE archs excluded: capacity drops differ by construction)."""
    import dataclasses
    cfg = get_config(arch + "-smoke")
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    model = make_model(cfg)
    params = P.init(jax.random.PRNGKey(1), model.param_specs())
    batch = _batch(cfg, jax.random.PRNGKey(3), with_labels=False)
    logits_p, cache = jax.jit(lambda p, b: model.prefill(p, b, MAX))(params, batch)
    nxt = jnp.argmax(logits_p, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S + (cfg.num_vision_tokens or 0), jnp.int32)
    logits_d, _ = jax.jit(model.decode_step)(params, nxt, pos, cache)
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], axis=1))
    logits_f, _ = jax.jit(lambda p, b: model.prefill(p, b, MAX))(params, batch2)
    rel = float(jnp.max(jnp.abs(logits_d - logits_f))) / \
        (float(jnp.max(jnp.abs(logits_f))) + 1e-9)
    assert rel < 0.08, (arch, rel)


def test_all_40_cells_enumerated():
    from repro.configs import arch_shape_cells
    cells = list(arch_shape_cells(include_skipped=True))
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    # documented skips: long_500k for 4 full-attention archs + whisper
    assert {(a, s) for a, s, ok, _ in skips} == {
        ("qwen2-0.5b", "long_500k"), ("gemma-2b", "long_500k"),
        ("paligemma-3b", "long_500k"), ("whisper-small", "long_500k"),
        ("qwen3-moe-30b-a3b", "long_500k")}
