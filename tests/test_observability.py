"""Observability: the labeled metrics registry (render -> parse round-trip),
request-lifecycle tracing (every span closed, phase spans tile the lifetime,
one contiguous trace across a live migration), the SLO-miss attribution
decomposition, and the profiler-window fixes that ride along."""
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import MetricsRegistry, parse_exposition
from repro.core.profiler import Profiler, SeriesWindow
from repro.core.tracing import (PHASES, Tracer, attribute_slo_misses,
                                format_attribution, trace_id_hex)
from repro.serving import InferenceEngine, Request, SamplingParams

ARCH = "qwen2-0.5b-smoke"


def _mk(backend="dense", **kw):
    cfg = get_config(ARCH)
    kw.setdefault("capacity", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("seed", 0)
    if backend == "paged":
        kw.setdefault("block_size", 8)
    return cfg, InferenceEngine(cfg, kv_backend=backend, **kw)


def _drain(engines, t=0.0, max_steps=300):
    """Step engines on the logical clock until drained; returns final t."""
    engines = engines if isinstance(engines, (list, tuple)) else [engines]
    for _ in range(max_steps):
        if not any(e.pending() for e in engines):
            break
        for e in engines:
            e.step(now=t)
        t += 1.0
    assert not any(e.pending() for e in engines), "engines never drained"
    return t


# --------------------------------------------------------------- metrics
def test_registry_render_parse_round_trip():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Requests served", ("replica", "kind"))
    c.inc(replica="0", kind="ok")
    c.inc(2.5, replica="0", kind="err")
    g = reg.gauge("queue_depth", "Queue depth")
    g.set(7)
    h = reg.histogram("step_seconds", "Step latency", ("phase",),
                      buckets=(0.1, 1.0))
    h.observe(0.05, phase="decode")
    h.observe(0.5, phase="decode")
    h.observe(5.0, phase="decode")
    text = reg.render()
    exp = parse_exposition(text)
    assert exp.value("requests_total", replica="0", kind="ok") == 1.0
    assert exp.value("requests_total", replica="0", kind="err") == 2.5
    assert exp.value("queue_depth") == 7.0
    assert exp.types["step_seconds"] == "histogram"
    assert exp.value("step_seconds_count", phase="decode") == 3.0
    assert exp.value("step_seconds_bucket", le="0.1", phase="decode") == 1.0
    assert exp.value("step_seconds_bucket", le="1", phase="decode") == 2.0
    assert exp.value("step_seconds_bucket", le="+Inf", phase="decode") == 3.0
    # rendering is deterministic (sorted) -> a second render is identical
    assert reg.render() == text


def test_exposition_label_escaping_round_trips():
    reg = MetricsRegistry()
    c = reg.counter("odd_total", "Odd label values", ("path",))
    nasty = 'v"q\\nl\nz'
    c.inc(path=nasty)
    exp = parse_exposition(reg.render())
    assert exp.value("odd_total", path=nasty) == 1.0


def test_registry_rejects_type_and_label_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "c", ("a",))
    with pytest.raises(ValueError):
        reg.gauge("x_total", "now a gauge")          # type clash
    with pytest.raises(ValueError):
        reg.counter("x_total", "c", ("a", "b"))      # labelnames clash
    with pytest.raises(ValueError):
        c.inc(b="z")                                 # unknown label
    with pytest.raises(ValueError):
        c.inc(-1.0, a="v")                           # counters are monotonic
    # idempotent re-registration hands back the same instrument
    assert reg.counter("x_total", "c", ("a",)) is c


def test_counter_peg_is_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("mirror_total", "pegged from a cumulative source")
    c.peg(5.0)
    c.peg(3.0)          # source re-read lower (e.g. registry rebind): keep max
    assert c.value() == 5.0
    c.peg(9.0)
    assert c.value() == 9.0


def test_parse_exposition_rejects_malformed():
    for bad in (
        "nope{unclosed 1\n",
        "# TYPE h histogram\nh_bucket{le=\"1.0\"} 3\nh_bucket{le=\"+Inf\"} 2\n"
        "h_sum 1\nh_count 2\n",                      # non-cumulative buckets
        "dup 1\ndup 2\n",                            # duplicate series
    ):
        with pytest.raises(ValueError):
            parse_exposition(bad)


# -------------------------------------------------------------- profiler
def test_series_window_rate_uses_observed_span():
    """Satellite fix: 3 events in the first 2s of a 15s window is 1.5/s,
    not 3/15 — the early-window rate must divide by the observed span."""
    w = SeriesWindow(window_s=15.0)
    for t in (0.0, 1.0, 2.0):
        w.observe(t, 1.0)
    assert w.rate(2.0) == pytest.approx(3.0 / 2.0)
    # single sample / zero span: fall back to the full window, not div-by-0
    w2 = SeriesWindow(window_s=15.0)
    w2.observe(0.0, 1.0)
    assert w2.rate(0.0) == pytest.approx(1.0 / 15.0)
    # steady state unchanged: a full window divides by window_s
    w3 = SeriesWindow(window_s=2.0)
    for t in np.arange(0.0, 6.0, 0.5):
        w3.observe(float(t), 1.0)
    assert w3.rate(5.5) == pytest.approx(w3.count(5.5) / 2.0)


def test_profiler_token_rate_early_window():
    p = Profiler(window_s=15.0)
    p.observe_tokens("decode", 0.0, 10)
    p.observe_tokens("decode", 2.0, 10)
    assert p.token_rate("decode", now=2.0) == pytest.approx(10.0)


def test_profiler_bottlenecks_rejects_unknown_metric():
    p = Profiler()
    p.observe_latency("prefill", 0.0, 0.1)
    with pytest.raises(ValueError, match="unknown bottleneck metric"):
        p.bottlenecks(metric="p50")
    assert p.bottlenecks(metric="p99")      # valid metrics still work


def test_profiler_mirrors_into_registry():
    reg = MetricsRegistry()
    p = Profiler(registry=reg)
    p.observe_latency("decode", 0.0, 0.2)
    p.observe_tokens("decode", 0.0, 32)
    exp = parse_exposition(reg.render())
    assert exp.value("profiler_latency_seconds_count", target="decode") == 1.0
    assert exp.value("profiler_tokens_total", target="decode") == 32.0


# ---------------------------------------------------------------- tracer
def test_tracer_verify_catches_open_and_overlap():
    tr = Tracer()
    tr.start_trace(1, 0.0)
    tr.begin(1, "queue_wait", 0.0)
    assert any("never closed" in p for p in tr.verify())
    tr.end(1, "queue_wait", 2.0)
    tr.begin(1, "prefill", 1.0)             # overlaps queue_wait
    tr.finish(1, 3.0)
    assert any("overlap" in p for p in tr.verify(1))

    ok = Tracer()
    ok.start_trace(2, 0.0)
    ok.begin(2, "queue_wait", 0.0)
    ok.end(2, "queue_wait", 1.0)
    ok.begin(2, "prefill", 1.0)             # shared endpoint = clean tiling
    ok.end(2, "prefill", 2.0)
    ok.begin(2, "decode", 2.5)              # 0.5 hole
    ok.finish(2, 3.0)
    assert ok.verify() == []
    assert ok.gaps(2) == [(2.0, 2.5)]


def test_tracer_rid_reuse_archives_incarnations():
    tr = Tracer()
    tr.start_trace(5, 0.0)
    root = tr.start_trace(5, 1.0)           # root still open: same trace
    assert root.t0 == 0.0
    tr.finish(5, 2.0)
    tr.start_trace(5, 10.0)                 # rid recycled: new incarnation
    tr.finish(5, 11.0)
    assert sum(1 for _ in tr.traces()) == 2
    assert tr.verify() == []


def test_chrome_trace_is_json_and_has_metadata():
    tr = Tracer()
    tr.start_trace(3, 0.0, replica="0")
    tr.begin(3, "decode", 0.0, replica="0")
    tr.finish(3, 1.0)
    doc = json.loads(json.dumps(tr.chrome_trace()))
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in spans} == {"request", "decode"}
    assert all(e["tid"] == 3 for e in spans)
    assert spans[0]["args"]["trace_id"] == trace_id_hex(3)


# ------------------------------------------------------ engine integration
def test_engine_traces_close_tile_and_follow_taxonomy(rng):
    """A mixed bucketed/chunked dense serve: every trace closes, phase spans
    tile each lifetime gaplessly, and span names follow the taxonomy."""
    cfg, eng = _mk()
    lens = (5, 11, 40, 7, 23, 6)             # 40 -> chunked prefill
    for i, n in enumerate(lens):
        eng.submit(Request(rid=i,
                           prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, n)],
                           sampling=SamplingParams(max_new_tokens=4)),
                   now=0.0)
    _drain(eng)
    assert len(eng.finished) == len(lens)
    assert eng.tracer.verify() == []
    for i, n in enumerate(lens):
        assert eng.tracer.gaps(i) == []
        names = [s.name for s in eng.tracer.spans(i)]
        assert names[0] == "request"
        for ph in PHASES:
            assert ph in names
        assert "admission" in names
        chunks = [s for s in names if s.startswith("prefill_chunk")]
        assert chunks == [f"prefill_chunk[{k}]" for k in range(len(chunks))]
        if n > 32:
            assert len(chunks) > 1, "long prompt should prefill in chunks"
    exp = parse_exposition(eng.metrics.render())
    assert exp.value("engine_requests_finished_total",
                     replica="0", reason="length") == float(len(lens))


@pytest.mark.parametrize("shared_tracer", [True, False])
def test_mid_decode_migration_yields_one_contiguous_trace(rng, shared_tracer):
    """The acceptance property: a paged request migrated mid-decode produces
    ONE contiguous trace spanning both replicas — decode closes on the
    source exactly where it reopens on the destination, the transfer is
    annotated, and nothing is orphaned.  With independent tracers the
    destination continues the span context from the migration payload and
    the source's incarnation is finished as migrated-out."""
    from repro.core.migration import MigrationManager
    cfg, a = _mk("paged")
    _, b = _mk("paged")
    b.params = a.params
    a.lb_id, b.lb_id = 0, 1
    tracer = Tracer()
    reg = MetricsRegistry()
    if shared_tracer:
        a.set_tracer(tracer)
        b.set_tracer(tracer)
    a.set_metrics(reg)
    b.set_metrics(reg)

    req = Request(rid=0,
                  prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 20)],
                  sampling=SamplingParams(max_new_tokens=8))
    a.submit(req, now=0.0)
    t = 0.0
    while len(req.output) < 2:               # chunked prefill + some decode
        a.step(now=t)
        t += 1.0
    assert req.state.name == "DECODE"
    mgr = MigrationManager()
    mgr.attach_metrics(reg)
    ev = mgr.migrate(a, b, rid=0, now=t, src_idx=0, dst_idx=1)
    assert ev is not None and ev.phase == "decode"
    _drain(b, t=t + 1.0)
    assert len(b.finished) == 1

    dst_tracer = tracer if shared_tracer else b.tracer
    assert dst_tracer.verify() == []
    assert dst_tracer.gaps(0) == []
    spans = dst_tracer.spans(0)
    if shared_tracer:
        # both replicas' spans in one trace, decode handed off edge-to-edge
        assert {s.replica for s in spans if s.replica is not None} == {"0", "1"}
        decode = [s for s in spans if s.name == "decode"]
        assert len(decode) == 2
        assert decode[0].status == "migrate-out"
        assert decode[0].t1 == decode[1].t0 == t
        assert decode[1].attrs.get("migrated_in") is True
    else:
        # span ids continue from the exported context: no id collisions,
        # and the source's trace is closed out rather than orphaned
        src_ids = {s.span_id for s in a.tracer.spans(0)}
        assert src_ids.isdisjoint({s.span_id for s in spans})
        assert a.tracer.verify() == []
        root = a.tracer.spans(0)[0]
        assert root.status == "migrated-out"
    transfer = [s for s in spans if s.name == "migration_transfer"]
    assert len(transfer) == 1 and transfer[0].attrs["bytes"] == ev.bytes
    exp = parse_exposition(reg.render())
    assert exp.value("migration_success_total", phase="decode") == 1.0
    a.prefix.check_invariants()
    b.prefix.check_invariants()


def test_migration_rollback_and_requeue_keep_trace_clean(rng, monkeypatch):
    """Failure paths must not orphan spans: a dst-full rollback re-opens
    decode on the source, and a both-sides-refuse requeue re-opens
    queue_wait — the request still finishes with a closed, gapless trace."""
    from repro.core.migration import MigrationManager
    cfg, a = _mk("paged", capacity=1)
    _, b = _mk("paged", capacity=1)
    b.params = a.params
    a.lb_id, b.lb_id = 0, 1
    tracer = Tracer()
    a.set_tracer(tracer)
    b.set_tracer(tracer)
    a.submit(Request(rid=0,
                     prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 10)],
                     sampling=SamplingParams(max_new_tokens=8)), now=0.0)
    b.submit(Request(rid=1,
                     prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, 10)],
                     sampling=SamplingParams(max_new_tokens=8)), now=0.0)
    t = 1.0
    for _ in range(3):
        a.step(now=t)
        b.step(now=t)
        t += 1.0

    mgr = MigrationManager()
    # destination full -> rollback adopts back into the source
    assert mgr.migrate(a, b, rid=0, now=t) is None
    assert mgr.failures[-1].reason == "dst-full"
    assert tracer.gaps(0) == []
    # drain b, then force both engines to refuse -> explicit requeue
    t = _drain(b, t=t + 1.0)
    real_adopt = a.adopt
    monkeypatch.setattr(a, "adopt", lambda req, payload, now=None: False)
    monkeypatch.setattr(b, "adopt", lambda req, payload, now=None: False)
    assert mgr.migrate(a, b, rid=0, now=t) is None
    assert mgr.failures[-1].reason == "requeued"
    qw = tracer.open_span(0, "queue_wait")
    assert qw is not None and qw.attrs.get("requeued") is True
    monkeypatch.setattr(a, "adopt", real_adopt)
    _drain(a, t=t + 1.0)
    assert len(a.finished) == 1
    assert tracer.verify() == []
    assert tracer.gaps(0) == []


def test_rejections_close_traces():
    cfg, eng = _mk()
    too_long = list(range(eng.max_len + 8))
    eng.submit(Request(rid=0, prompt=too_long,
                       sampling=SamplingParams(max_new_tokens=2)), now=0.0)
    spans = eng.tracer.spans(0)
    assert spans and spans[0].status == "rejected:prompt-too-long"
    assert eng.tracer.verify() == []
    exp = parse_exposition(eng.metrics.render())
    assert exp.value("serving_rejections_total",
                     replica="0", reason="prompt-too-long") == 1.0


def test_traces_stay_closed_under_random_traffic(rng):
    """Property-style sweep: random prompt mixes (bucketed/chunked) across
    recycled rids always drain to a tracer with zero integrity violations —
    every span closed, no phase overlap, no coverage gaps."""
    cfg, eng = _mk()
    for round_ in range(3):
        n = int(rng.integers(3, 7))
        for i in range(n):
            ln = int(rng.integers(3, 48))
            eng.submit(Request(rid=i,
                               prompt=[int(x) for x in rng.integers(0, cfg.vocab_size, ln)],
                               sampling=SamplingParams(
                                   max_new_tokens=int(rng.integers(1, 6)))),
                       now=float(round_ * 1000))
        _drain(eng, t=float(round_ * 1000))
        for i in range(n):
            assert eng.tracer.gaps(i) == []
        eng.finished.clear()
    assert eng.tracer.verify() == []


# ------------------------------------------------------------- front-end
def test_completions_api_ids_derive_from_trace_id(rng):
    from repro.serving import CompletionRequest, CompletionsAPI
    cfg, eng = _mk()
    api = CompletionsAPI(eng, model=ARCH)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size, 9)]
    resp = api.create(CompletionRequest(prompt=list(prompt), model=ARCH,
                                        max_tokens=4),
                      now=0.0)
    assert resp.x_trace_id is not None
    assert len(resp.x_trace_id) == 16
    assert int(resp.x_trace_id, 16) >= 0
    assert resp.id == f"cmpl-{resp.x_trace_id}"
    # the id joins into the tracer: that trace exists and is closed
    rid = int(resp.x_trace_id, 16)
    assert eng.tracer.spans(rid) and eng.tracer.verify(rid) == []

    chunks = list(api.stream(CompletionRequest(prompt=list(prompt), model=ARCH,
                                               max_tokens=4, stream=True),
                             now=100.0))
    cid = chunks[0].id
    assert cid.startswith("cmpl-") and len(cid) == len("cmpl-") + 16
    assert all(c.id == cid for c in chunks), "stream id must be stable"
    assert cid != resp.id, "distinct requests get distinct trace ids"


# ------------------------------------------------------------ attribution
def test_slo_attribution_names_dominant_phase():
    tr = Tracer()
    tr.start_trace(7, 0.0)
    tr.begin(7, "queue_wait", 0.0)
    tr.end(7, "queue_wait", 8.0)
    tr.begin(7, "prefill", 8.0)
    tr.end(7, "prefill", 9.0)
    tr.begin(7, "decode", 9.0)
    tr.finish(7, 12.0)
    r = Request(rid=7, prompt=[1, 2, 3], sampling=SamplingParams(),
                slo_ttft=2.0, slo_tpot=0.5)
    r.arrival = 0.0
    r.t_first_token = 9.0
    r.token_times = [9.0, 10.5, 12.0]
    rows = attribute_slo_misses(tr, [r])
    assert [row["slo"] for row in rows] == ["ttft", "tpot"]
    ttft, tpot = rows
    assert ttft["dominant"] == "queue_wait"
    assert ttft["queue_wait"] == pytest.approx(8.0)
    assert ttft["prefill"] == pytest.approx(1.0)
    assert ttft["trace_id"] == trace_id_hex(7)
    # the decode window has no queue/prefill/migration time: pure stall
    assert tpot["dominant"] == "decode_stall"
    assert tpot["decode_stall"] == pytest.approx(3.0)
    table = format_attribution(rows)
    assert "queue_wait" in table and "decode_stall" in table
    # a request inside its SLOs contributes no rows
    ok = Request(rid=7, prompt=[1], sampling=SamplingParams(), slo_ttft=20.0)
    ok.arrival, ok.t_first_token = 0.0, 9.0
    assert attribute_slo_misses(tr, [ok]) == []


def test_attribution_counts_migration_window():
    tr = Tracer()
    tr.start_trace(4, 0.0)
    tr.begin(4, "queue_wait", 0.0)
    tr.end(4, "queue_wait", 1.0)
    tr.begin(4, "prefill", 1.0)
    tr.end(4, "prefill", 2.0)
    tr.begin(4, "decode", 2.0)
    tr.annotate(4, "migration_transfer", 5.0, duration_s=6.0)
    tr.finish(4, 12.0)
    r = Request(rid=4, prompt=[1], sampling=SamplingParams(), slo_tpot=0.5)
    r.arrival, r.t_first_token = 0.0, 2.0
    r.token_times = [2.0, 12.0]
    rows = attribute_slo_misses(tr, [r])
    assert len(rows) == 1 and rows[0]["slo"] == "tpot"
    assert rows[0]["migration"] == pytest.approx(6.0)
    assert rows[0]["dominant"] == "migration"
