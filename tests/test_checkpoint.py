"""Checkpoint/restart fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training import checkpoint as CKPT
from repro.training.data import DataConfig
from repro.training.train_loop import Trainer, TrainConfig

pytestmark = pytest.mark.slow  # trainer crash/restart loops


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 7, t, metadata={"loss": 1.5})
    out, manifest = CKPT.restore(str(tmp_path), 7, t)
    assert manifest["step"] == 7 and manifest["metadata"]["loss"] == 1.5
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 5, t)
    # simulate a crash mid-save of step 9: directory without COMMIT
    broken = tmp_path / "step_00000009"
    os.makedirs(broken)
    (broken / "manifest.json").write_text("{}")
    out, manifest = CKPT.restore_latest(str(tmp_path), t)
    assert manifest["step"] == 5


def test_retention_keeps_last_k(tmp_path):
    t = _tree()
    for s in range(1, 7):
        CKPT.save(str(tmp_path), s, t, keep_last=3)
    assert CKPT.list_steps(str(tmp_path)) == [4, 5, 6]


def test_async_saver_commits(tmp_path):
    t = _tree()
    s = CKPT.AsyncSaver()
    s.save(str(tmp_path), 3, t)
    s.wait()
    assert CKPT.list_steps(str(tmp_path)) == [3]


def test_trainer_crash_restart_is_deterministic(tmp_path):
    cfg = get_config("qwen2-0.5b-smoke")
    dcfg = DataConfig(batch=2, seq_len=16)
    a_dir, b_dir = str(tmp_path / "a"), str(tmp_path / "b")

    losses_a = Trainer(cfg, TrainConfig(steps=8, ckpt_every=3, ckpt_dir=a_dir,
                                        log_every=100, async_ckpt=False),
                       dcfg).run()
    with pytest.raises(RuntimeError):
        Trainer(cfg, TrainConfig(steps=8, ckpt_every=3, ckpt_dir=b_dir,
                                 log_every=100, async_ckpt=False), dcfg,
                fail_at_step=4).run()
    t2 = Trainer(cfg, TrainConfig(steps=8, ckpt_every=3, ckpt_dir=b_dir,
                                  log_every=100, async_ckpt=False), dcfg)
    assert t2.start_step == 3
    losses_b = t2.run()
    np.testing.assert_allclose(losses_a[3:], losses_b, atol=1e-5)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written from replicated arrays restores under explicit
    shardings (single-device here; the mechanism is mesh-agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec
    t = _tree()
    CKPT.save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), t)
    out, _ = CKPT.restore(str(tmp_path), 1, t, shardings=sh)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
