"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices."""
import pytest


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
