"""Autoscaling control-law edges and proactive-vs-reactive mini scenarios.

The scenario tests run a deterministic fluid-queue model (tokens in /
tokens out per logical step, replica warm-up lag, no engine, no noise):
fast enough for the fast tier, exact enough to assert *when* each
controller fires.  The full-engine versions of these scenarios live in
``benchmarks/engine_bench.py --mode proactive``."""
import math

import pytest

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.core.scaling_policy import (ProactiveConfig,
                                       ProactiveScalingPolicy,
                                       ScalingSignals)


def _sig(**kw) -> ScalingSignals:
    kw.setdefault("warm_replicas", 1)
    kw.setdefault("total_replicas", 1)
    return ScalingSignals(**kw)


class _StubPolicy:
    """Fixed-output policy: isolates the autoscaler's shared behaviors
    (clamp, stabilization, cooldowns) from any planning logic."""

    def __init__(self, wants):
        self.wants = list(wants)
        self.forecast = 0.0

    def on_control_tick(self, t, sig):
        pass

    def desired_replicas(self, t, current, sig):
        return self.wants.pop(0) if self.wants else current


# ------------------------------------------------- shared control-law edges
def test_tolerance_dead_band_boundary():
    """|ratio - 1| <= tolerance holds exactly at the boundary; one epsilon
    past it acts."""
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.25, max_replicas=100))
    assert a.evaluate(0.0, 4, 1.25) == 4          # ratio 1.25: on the edge
    assert a.evaluate(1.0, 4, 1.3) == 6           # past it: ceil(4*1.3)


def test_scale_down_stabilization_window_max():
    """Scale-down lands on the *max* desired inside the window — not the
    latest, not the min — so one quiet sample can't flush capacity that a
    recent sample still justified."""
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.0, stabilization_s=30.0,
                             scale_down_cooldown_s=0.0, max_replicas=10))
    assert a.evaluate(0.0, 8, 1.0) == 8           # desired 8 in window
    assert a.evaluate(10.0, 8, 0.75) == 8         # desired 6: max(8,6)=8 holds
    # window slides past the 8-sample; the 6-sample now rules the floor
    assert a.evaluate(31.0, 8, 0.25) == 6         # desired 2, max(6,2)=6
    assert a.evaluate(62.0, 6, 0.25) == 2         # both stale: down to 2


def test_fresh_scale_up_blocks_down_flap():
    """The down cooldown counts from the last event in EITHER direction: a
    fresh scale-up pins the floor for scale_down_cooldown_s even when the
    metric collapses immediately (K8s semantics)."""
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.0, stabilization_s=0.0,
                             scale_down_cooldown_s=20.0, max_replicas=10))
    assert a.evaluate(0.0, 2, 3.0) == 6           # up event at t=0
    assert a.evaluate(5.0, 6, 0.1) == 6           # down blocked by fresh up
    assert a.evaluate(19.0, 6, 0.1) == 6          # still inside cooldown
    assert a.evaluate(21.0, 6, 0.1) == 1          # cooldown expired


def test_min_max_clamp_applies_to_policy_output():
    """A policy's raw desired count passes through the same min/max clamp
    as the HPA law — a runaway plan cannot exceed the replica budget."""
    a = Autoscaler(HPAConfig(target=1.0, min_replicas=2, max_replicas=5,
                             tolerance=0.0, stabilization_s=0.0,
                             scale_down_cooldown_s=0.0),
                   policy=_StubPolicy([50, 0]))
    assert a.evaluate(0.0, 3, 0.0, signals=_sig()) == 5
    assert a.evaluate(1.0, 5, 0.0, signals=_sig()) == 2


def test_policy_output_still_stabilized_and_cooled():
    """Flap protection is shared: a policy that oscillates wildly still
    cannot flap the replica count inside the stabilization window."""
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.0, stabilization_s=30.0,
                             scale_down_cooldown_s=30.0, max_replicas=10),
                   policy=_StubPolicy([8, 1, 1, 1]))
    assert a.evaluate(0.0, 2, 0.0, signals=_sig()) == 8
    assert a.evaluate(5.0, 8, 0.0, signals=_sig()) == 8    # cooldown + window
    assert a.evaluate(15.0, 8, 0.0, signals=_sig()) == 8
    assert a.evaluate(61.0, 8, 0.0, signals=_sig()) < 8    # both expired


def test_reactive_paths_unchanged_without_signals():
    """A policy-bearing autoscaler called without signals falls back to
    the plain HPA law — existing call sites keep their behavior."""
    a = Autoscaler(HPAConfig(target=1.0, tolerance=0.0, max_replicas=10),
                   policy=_StubPolicy([9, 9, 9]))
    assert a.evaluate(0.0, 2, 2.0) == 4           # ratio law, not the stub


# ----------------------------------------------------- policy unit behavior
def test_policy_horizon_defaults_to_warmup_plus_control_period():
    p = ProactiveScalingPolicy(cold_start_steps=8, control_every_steps=4)
    assert p.horizon_steps == 12
    q = ProactiveScalingPolicy(ProactiveConfig(horizon_steps=3),
                               cold_start_steps=8, control_every_steps=4)
    assert q.horizon_steps == 3


def test_capacity_learned_only_while_backlogged():
    """Idle ticks (queue empty) must not erode the capacity estimate: an
    idle replica serves 0 tokens/step but can do far better."""
    p = ProactiveScalingPolicy(ProactiveConfig(capacity_decay=1.0))
    p.on_control_tick(0.0, _sig(queue_depth=5, served_tokens=40, steps=4))
    assert p.capacity == pytest.approx(10.0)
    p.on_control_tick(4.0, _sig(queue_depth=0, served_tokens=0, steps=4))
    assert p.capacity == pytest.approx(10.0)      # idle tick ignored
    p.on_control_tick(8.0, _sig(queue_depth=3, served_tokens=24, steps=4))
    assert p.capacity == pytest.approx(6.0)       # backlogged tick learned


def test_goodput_guard_blocks_scale_down():
    """With goodput under the floor the policy refuses to surrender
    replicas even when the forecast says fewer would do."""
    class _Req:
        def __init__(self, ok):
            self.slo_ttft, self.slo_tpot, self._ok = 1.0, None, ok

        def slo_met(self):
            return self._ok

    p = ProactiveScalingPolicy(ProactiveConfig(goodput_floor=0.9))
    p.observe_outcomes([_Req(False), _Req(False), _Req(True)], [])
    assert p.goodput() == pytest.approx(1 / 3)
    p.on_control_tick(0.0, _sig())                # forecast ~0 => wants 1
    assert p.desired_replicas(0.0, 4, _sig()) == 4    # guard holds at 4
    p.observe_outcomes([_Req(True) for _ in range(60)], [])
    assert p.desired_replicas(0.0, 4, _sig()) == 1    # goodput recovered


def test_queue_miss_bias_boosts_and_decays():
    """A queue_wait-dominated SLO miss means the plan was short: the next
    miss_patience control ticks bid current + queue_miss_boost even when
    the forecast alone would not."""
    p = ProactiveScalingPolicy(ProactiveConfig(miss_patience=2,
                                               queue_miss_boost=2))
    p.observe_outcomes([], [{"dominant": "queue_wait"}])
    p.on_control_tick(0.0, _sig())
    assert p.desired_replicas(0.0, 3, _sig()) == 5
    p.on_control_tick(4.0, _sig())
    assert p.desired_replicas(4.0, 3, _sig()) == 5
    p.on_control_tick(8.0, _sig())                # patience exhausted
    # bias gone and the outcome window is healthy: the ~0 forecast rules
    assert p.desired_replicas(8.0, 3, _sig()) == 1
    p2 = ProactiveScalingPolicy(ProactiveConfig(miss_patience=1))
    p2.observe_outcomes([], [{"dominant": "prefill"}])   # not queue-dominated
    p2.on_control_tick(0.0, _sig())
    assert p2.desired_replicas(0.0, 3, _sig()) == 1


def test_policy_forecast_error_tracks_realized_load():
    """The realized-error gauge compares the forecast made one horizon ago
    against the arrival rate actually observed when that horizon lands."""
    p = ProactiveScalingPolicy(ProactiveConfig(predictor="ewma",
                                               horizon_steps=4),
                               control_every_steps=4)
    for tick in range(6):
        for _ in range(10):
            p.note_arrival(float(4 * tick), 4.0)  # steady 10 req * 4 tok
        p.on_control_tick(float(4 * tick), _sig(steps=4))
    # steady load, EWMA forecast == rate => realized error ~ 0
    assert p.forecast == pytest.approx(10.0)
    assert p.forecast_error == pytest.approx(0.0, abs=1e-9)


# ------------------------------------------------- deterministic scenarios
def _simulate(mode: str, lams: list[float], *, cold: int = 8,
              control_every: int = 4, cap: float = 20.0,
              work: float = 20.0, max_replicas: int = 8):
    """Fluid-queue cluster: ``lams[t] * work`` tokens arrive at step t,
    each warm replica drains ``cap`` tokens/step, scale-ups take ``cold``
    steps to warm.  Returns (first_scaleup_step, replica_trace)."""
    hpa = HPAConfig(metric="queue", target=6.0, tolerance=0.1,
                    min_replicas=1, max_replicas=max_replicas,
                    stabilization_s=16.0, scale_down_cooldown_s=16.0)
    policy = None
    if mode == "proactive":
        policy = ProactiveScalingPolicy(
            ProactiveConfig(), cold_start_steps=cold,
            control_every_steps=control_every)
    scaler = Autoscaler(hpa, policy=policy)
    queue, replicas, served_acc = 0.0, 1, 0.0
    warming: list[tuple[int, int]] = []       # (ready_step, count)
    first_up, trace = None, []
    for t, lam in enumerate(lams):
        arr = lam * work
        if policy is not None and arr > 0:
            policy.note_arrival(float(t), arr)
        warm = replicas - sum(c for ready, c in warming if ready > t)
        served = min(queue + arr, warm * cap)
        queue += arr - served
        served_acc += served
        if t % control_every == 0:
            depth = queue / work
            if policy is not None:
                sig = ScalingSignals(
                    queue_depth=int(math.ceil(depth)),
                    queue_tokens=int(queue), served_tokens=int(served_acc),
                    steps=control_every, warm_replicas=max(warm, 0),
                    total_replicas=replicas)
                new = scaler.evaluate(float(t), replicas, 0.0, signals=sig)
            else:
                new = scaler.evaluate(float(t), replicas, depth)
            served_acc = 0.0
            if new > replicas:
                if first_up is None:
                    first_up = t
                warming.append((t + cold, new - replicas))
            replicas = new
        trace.append(replicas)
    return first_up, trace


COLD = 8


def _flash_lams(quiet=0.1, hot=3.0, ramp=8, onset=24):
    return ([quiet] * onset
            + [quiet + (hot - quiet) * (i + 1) / ramp for i in range(ramp)]
            + [hot] * 60)


def test_mini_flash_proactive_leads_by_warmup():
    """Flash crowd: the forecaster extrapolates the ramp and fires at
    least a full warm-up earlier than the queue-triggered reactive law —
    the whole point of forecasting at the cold-start horizon."""
    lams = _flash_lams()
    re_up, _ = _simulate("reactive", lams, cold=COLD)
    pr_up, _ = _simulate("proactive", lams, cold=COLD)
    assert re_up is not None and pr_up is not None
    assert pr_up <= re_up - COLD, \
        f"proactive fired at {pr_up}, reactive at {re_up}: lead < {COLD}"


def test_mini_diurnal_proactive_leads_by_warmup():
    """Diurnal upswing: a smooth sinusoidal rise is the friendliest
    possible signal for the trend term — the lead must cover warm-up."""
    lams = [0.1 + 2.4 * 0.5 * (1 + math.sin(2 * math.pi * t / 96
                                            - math.pi / 2))
            for t in range(96)]
    re_up, _ = _simulate("reactive", lams, cold=COLD)
    pr_up, _ = _simulate("proactive", lams, cold=COLD)
    assert re_up is not None and pr_up is not None
    assert pr_up <= re_up - COLD, \
        f"proactive fired at {pr_up}, reactive at {re_up}: lead < {COLD}"


def test_mini_hotspot_proactive_leads_by_warmup():
    """Tenant hotspot: steady background plus one tenant ramping hot.
    The aggregate arrival signal carries the ramp; the forecast fires
    before the queue the hotspot causes ever builds."""
    steady = [0.4] * 96
    hot = [0.0] * 32 + [2.6 * min((i + 1) / 8, 1.0) for i in range(64)]
    lams = [a + b for a, b in zip(steady, hot)]
    re_up, _ = _simulate("reactive", lams, cold=COLD)
    pr_up, _ = _simulate("proactive", lams, cold=COLD)
    assert re_up is not None and pr_up is not None
    assert pr_up <= re_up - COLD, \
        f"proactive fired at {pr_up}, reactive at {re_up}: lead < {COLD}"


def test_mini_scenarios_scale_back_down():
    """After the spike passes both controllers release replicas; the
    proactive goodput guard must not pin the fleet at peak forever."""
    lams = _flash_lams() + [0.05] * 120
    for mode in ("reactive", "proactive"):
        _, trace = _simulate(mode, lams, cold=COLD)
        assert max(trace) > 1, f"{mode}: never scaled up"
        assert trace[-1] < max(trace), f"{mode}: never scaled back down"
