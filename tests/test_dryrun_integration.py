"""Dry-run integration: one real lower+compile per mesh via subprocess
(the 512-device XLA flag must not leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess lower+compile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_one_cell_compiles(mesh, tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--mesh", mesh, "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["fits_hbm"]
    assert rec["flops_per_device"] > 0
    assert rec["bytes_per_device"] > 0
    assert rec["memory"]["peak_bytes"] > 0


def test_documented_skip_is_reported(tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "long_500k", "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=240)
    assert r.returncode == 0
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "skip"
    assert "full-attention" in rec["reason"]
