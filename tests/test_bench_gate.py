"""The bench-regression CI gate: the committed baselines must pass against
themselves, and synthetically degraded metrics must fail (exit != 0)."""
import copy
import json
import pathlib

import pytest

import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "benchmarks"))
from check_regression import BASELINE_DIR, GATES, _dig, compare, main  # noqa: E402

BENCHES = sorted(GATES)


def _baseline(bench: str) -> dict:
    with open(BASELINE_DIR / f"BENCH_{bench}.json") as f:
        return json.load(f)


@pytest.mark.parametrize("bench", BENCHES)
def test_baseline_passes_against_itself(bench):
    base = _baseline(bench)
    assert compare(bench, base, base) == []


@pytest.mark.parametrize("bench", BENCHES)
def test_every_gated_metric_exists_in_baseline(bench):
    """Each gated metric path must resolve to a number in the committed
    baseline — a gate on a metric the bench no longer emits would
    otherwise silently rot (checked directly, independent of compare())."""
    base = _baseline(bench)
    for path in GATES[bench]:
        v = _dig(base, path)
        assert isinstance(v, (int, float)), f"{path} missing: {v!r}"


def _degrade(d: dict, path: str, higher: bool):
    parts = path.split(".")
    cur = d
    for p in parts[:-1]:
        cur = cur[p]
    v = float(cur[parts[-1]])
    # well past any tolerance+slack in either direction
    cur[parts[-1]] = v * 0.2 - 10 if higher else v * 5 + 10


@pytest.mark.parametrize("bench", BENCHES)
def test_degraded_metrics_fail(bench):
    base = _baseline(bench)
    for path, (higher, _, _) in GATES[bench].items():
        fresh = copy.deepcopy(base)
        _degrade(fresh, path, higher)
        fails = compare(bench, fresh, base)
        assert any(path in f for f in fails), \
            f"degrading {path} did not trip the gate"


def test_missing_metric_fails():
    base = _baseline("paged")
    fresh = copy.deepcopy(base)
    del fresh["paged"]["prefix_hit_rate"]
    assert any("missing" in f for f in compare("paged", fresh, base))


def test_cli_exit_codes(tmp_path):
    base = _baseline("directory")
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(base))
    assert main(["--bench", "directory", "--fresh", str(ok)]) == 0
    bad = copy.deepcopy(base)
    bad["directory"]["cluster_hit_rate"] *= 0.5
    badp = tmp_path / "bad.json"
    badp.write_text(json.dumps(bad))
    assert main(["--bench", "directory", "--fresh", str(badp)]) == 1
