"""Mamba-2 SSD chunked scan for TPU (pl.pallas_call + BlockSpec).

Grid (b, H, nc) with the chunk axis innermost: the inter-chunk state h
(P x N, fp32) persists in VMEM scratch across the sequential chunk
iterations while the intra-chunk quadratic term runs on the MXU:

  M   = (C B^T) * L        -- (Q,Q) masked decay kernel
  y   = M (x*dt) + (C h) * exp(cum)
  h'  = exp(cum_Q) h + (B * wt)^T (x*dt)

Chunk Q and head dim P are MXU-aligned (Q=128/256, P=64/128); one grid cell
holds Q x max(P, N) fp32 tiles comfortably in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32


def _kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, y_ref, hout_ref, h_scr, *,
            Q, P, N, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(f32)          # (Q, P)
    Bm = b_ref[0, 0].astype(f32)         # (Q, N)
    Cm = c_ref[0, 0].astype(f32)         # (Q, N)
    dt = dt_ref[0, 0].astype(f32)        # (Q,)
    da = da_ref[0, 0].astype(f32)        # (Q,)

    cum = jnp.cumsum(da)                                     # (Q,)
    seg = cum[:, None] - cum[None, :]                        # (q, t)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ti <= qi, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)     # (q, t)
    M = CB * L
    xdt = x * dt[:, None]                                    # (Q, P)
    y_in = jax.lax.dot_general(M, xdt, (((1,), (0,)), ((), ())),
                               preferred_element_type=f32)   # (Q, P)
    h = h_scr[...]                                           # (P, N)
    y_off = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)  # (Q, P)
    y_off = y_off * jnp.exp(cum)[:, None]
    y_ref[0, 0] = (y_in + y_off).astype(y_ref.dtype)

    wt = jnp.exp(cum[Q - 1] - cum)                           # (Q,)
    dh = jax.lax.dot_general(xdt, Bm * wt[:, None], (((0,), (0,)), ((), ())),
                             preferred_element_type=f32)     # (P, N)
    h_scr[...] = h * jnp.exp(cum[Q - 1]) + dh

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_scr[...]


def ssd_scan(x, B, C, dt, da, *, chunk: int = 128, interpret: bool = True):
    """x (b,S,H,P); B,C (b,S,H,N) group-expanded; dt,da (b,S,H) f32.
    Returns (y (b,S,H,P) f32, h_last (b,H,P,N) f32)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    # kernel layout: head-major so one grid cell streams (Q,P)/(Q,N) tiles
    xt = x.transpose(0, 2, 1, 3)          # (b,H,S,P)
    Bt = B.transpose(0, 2, 1, 3)          # (b,H,S,N)
    Ct = C.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)           # (b,H,S)
    dat = da.transpose(0, 2, 1)

    kern = functools.partial(_kernel, Q=Q, P=P, N=N, nc=nc)
    y, h_last = pl.pallas_call(
        kern,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda i, h, c: (i, h, c)),
            pl.BlockSpec((1, 1, Q), lambda i, h, c: (i, h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, S, P), f32),
            jax.ShapeDtypeStruct((b, H, P, N), f32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), f32)],
        interpret=interpret,
    )(xt, Bt, Ct, dtt, dat)
    return y.transpose(0, 2, 1, 3), h_last
