"""jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_chunked_scan(x, B, C, dt, da, *, chunk: int = 128,
                     use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return ssd_scan_ref(x, B, C, dt, da, chunk=chunk)
    return ssd_scan(x, B, C, dt, da, chunk=chunk, interpret=interpret)
