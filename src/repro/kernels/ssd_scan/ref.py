"""Pure-jnp oracle for the SSD chunked-scan kernel.

Computes the Mamba-2 state-space-dual recurrence over pre-activated inputs:
  h_t = exp(da_t) h_{t-1} + dt_t B_t x_t^T          (per head)
  y_t = C_t h_t
chunked exactly like models/mamba.ssd_apply_full (same math, no conv/gating
— the kernel covers the scan hot loop only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def ssd_scan_ref(x, B, C, dt, da, *, chunk: int):
    """x (b,S,H,P); B,C (b,S,H,N) [group-expanded]; dt,da (b,S,H) f32.
    Returns (y (b,S,H,P) f32, h_last (b,H,P,N) f32)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = chunk
    assert S % Q == 0
    nc = S // Q

    def chunkify(t):
        return t.reshape(b, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xq, Bq, Cq, dtq, daq = map(chunkify, (x, B, C, dt, da))

    def body(h, inp):
        xk, Bk, Ck, dtk, dak = inp
        cum = jnp.cumsum(dak, axis=1)                       # (b,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # (b,q,t,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bqhn,bthn->bqth", Ck.astype(f32), Bk.astype(f32))
        M = CB * L
        xdt = x_ = xk.astype(f32) * dtk[..., None]
        y_in = jnp.einsum("bqth,bthp->bqhp", M, xdt)
        y_off = jnp.einsum("bqhn,bhpn->bqhp", Ck.astype(f32), h) \
            * jnp.exp(cum)[..., None]
        wt = jnp.exp(cum[:, -1:, :] - cum)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bthn,bthp->bhpn", Bk.astype(f32) * wt[..., None], xdt)
        return h_new, y_in + y_off

    h0 = jnp.zeros((b, H, P, N), f32)
    h_last, ys = jax.lax.scan(body, h0, (xq, Bq, Cq, dtq, daq))
    return ys.swapaxes(0, 1).reshape(b, S, H, P), h_last
