"""Pure-jnp oracle for the paged decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def paged_attention_ref(q, k_pages, v_pages, block_table, context_len, *,
                        scale: float | None = None):
    """q: (B,H,d); pools (num_blocks, bs, KV, d); block_table (B, max_blk)
    int32 (-1 = unused); context_len (B,) valid positions.  -> (B,H,d)."""
    B, H, d = q.shape
    nb, bs, KV, _ = k_pages.shape
    max_blk = block_table.shape[1]
    rep = H // KV
    scale = d ** -0.5 if scale is None else scale

    bt = jnp.maximum(block_table, 0)
    k = k_pages[bt].reshape(B, max_blk * bs, KV, d)      # (B,S,KV,d)
    v = v_pages[bt].reshape(B, max_blk * bs, KV, d)
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(f32), kx.astype(f32)) * scale
    pos = jnp.arange(max_blk * bs)[None, :]
    valid = (pos < context_len[:, None]) & \
        (jnp.repeat(block_table >= 0, bs, axis=1))
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    e = jnp.exp(s - m)
    w = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bshd->bhd", w, vx.astype(f32)).astype(q.dtype)
