"""Paged decode attention for TPU (PagedAttention adapted to VMEM tiling).

The GPU original gathers KV blocks with per-warp address arithmetic; the TPU
adaptation streams whole pages HBM->VMEM through a block-table-driven index
map (scalar-prefetch grid spec: the table must be resident before the DMA
for grid step j can be issued).  Grid (B, KV, nblk) with the page axis
innermost; online-softmax state for all ``rep`` query heads of one kv head
sits in VMEM scratch across page iterations.

Dead pages (table == -1 or fully past context_len) skip their compute via
``pl.when``; their DMA is redirected to page 0 by the index map (clamped),
so no out-of-bounds traffic is issued.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG = -1e30


def _kernel(tbl_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, bs, nblk, rep, d):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    blk = tbl_ref[b, j]
    ctx = ctx_ref[b]
    live = jnp.logical_and(blk >= 0, j * bs < ctx)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(f32) * scale              # (rep, d)
        k = k_ref[0, 0].astype(f32)                      # (bs, d)
        v = v_ref[0, 0].astype(f32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)   # (rep, bs)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (rep, bs), 1)
        s = jnp.where(pos < ctx, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.exp(s - m_safe[:, None])
        alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        m_scr[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_table, context_len, *,
                    scale: float | None = None, interpret: bool = True):
    """q (B,H,d); pools (num_blocks, bs, KV, d); block_table (B, max_blk);
    context_len (B,) -> (B,H,d)."""
    B, H, d = q.shape
    nb, bs, KV, _ = k_pages.shape
    max_blk = block_table.shape[1]
    rep = H // KV
    scale = d ** -0.5 if scale is None else scale

    # (B, KV, rep, d) query layout: one grid cell owns one kv head's group
    qg = q.reshape(B, KV, rep, d)
    # pools to (num_blocks, KV, bs, d) so one (page, kv head) is a VMEM tile
    kp = k_pages.transpose(0, 2, 1, 3)
    vp = v_pages.transpose(0, 2, 1, 3)

    kern = functools.partial(_kernel, scale=scale, bs=bs, nblk=max_blk,
                             rep=rep, d=d)

    def page_map(b, g, j, tbl):
        return (jnp.maximum(tbl[b, j], 0), g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # block_table, context_len
        grid=(B, KV, max_blk),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda b, g, j, tbl, ctx: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b, g, j, tbl, ctx: (jnp.maximum(tbl[b, j], 0), g, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b, g, j, tbl, ctx: (jnp.maximum(tbl[b, j], 0), g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda b, g, j, tbl, ctx: (b, g, 0, 0)),
        scratch_shapes=[pltpu.VMEM((rep,), f32), pltpu.VMEM((rep,), f32),
                        pltpu.VMEM((rep, d), f32)],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, d), q.dtype),
        interpret=interpret,
    )(block_table, context_len, qg, kp, vp)
    return out.reshape(B, H, d)
