"""jit'd wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_table, context_len, *,
                           use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return paged_attention_ref(q, k_pages, v_pages, block_table, context_len)
    return paged_attention(q, k_pages, v_pages, block_table, context_len,
                           interpret=interpret)
