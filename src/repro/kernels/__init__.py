"""Pallas TPU kernels for the serving data path's compute hot spots.

Each subpackage ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper with ref/kernel dispatch) and ref.py (pure-jnp
oracle).  Validated on CPU with interpret=True (tests/test_kernels.py);
pass interpret=False on real TPU (PerfConfig.pallas_interpret).

flash_attention/   FA2-style blocked prefill attention (causal, GQA, SWA)
paged_attention/   decode attention over block-table paged KV
                   (scalar-prefetch grid: PagedAttention adapted to TPU DMA)
ssd_scan/          Mamba-2 SSD chunked scan (MXU intra-chunk + VMEM state)
"""
