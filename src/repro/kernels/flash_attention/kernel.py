"""FA2-style blocked attention for TPU (pl.pallas_call + BlockSpec).

Layout (B,H,S,d); grid (B, H, nq, nk) with the kv axis innermost — TPU grids
execute sequentially, so the online-softmax running state (m, l, acc) lives
in VMEM scratch that persists across the nk iterations of one (b,h,q) tile.
Block shapes are MXU-aligned: q/k tiles of 128/256 rows, head_dim lanes.

GQA is handled in the k/v index maps (kv head = h // rep), sliding windows
by masking whole tiles out via ``pl.when`` (a skipped tile costs one grid
step, no memory traffic: its DMA loads the same block as the previous step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32
NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, window, bq, bk, nk, sq, skv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile coordinates (kv may be longer than q: right-aligned q positions)
    off = skv - sq
    q0 = qi * bq + off          # absolute position of the q tile start
    k0 = ki * bk

    # whole-tile skip tests
    live = jnp.bool_(True)
    if causal:
        live &= k0 <= q0 + bq - 1
    if window:
        live &= (k0 + bk - 1) > (q0 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(f32) * scale             # (bq, d)
        k = k_ref[0, 0].astype(f32)                     # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)  # (bq, bk)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.exp(s - m_safe[:, None])                # (bq, bk)
        alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        v = v_ref[0, 0].astype(f32)                     # (bk, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B,H,Sq,d); k,v: (B,KV,Skv,d) -> (B,H,Sq,d).

    Sq/Skv must be multiples of bq/bk (ops.py pads).  ``interpret=True`` runs
    the kernel body on CPU for validation; on TPU pass False.
    """
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    rep = H // KV
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = d ** -0.5 if scale is None else scale

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, sq=Sq, skv=Skv)

    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=_scratch(bq, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq: int, d: int):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((bq,), f32), pltpu.VMEM((bq,), f32),
            pltpu.VMEM((bq, d), f32)]
