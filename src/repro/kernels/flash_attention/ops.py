"""jit'd wrapper: model layout <-> kernel layout, padding, dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "bq", "bk", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_pallas: bool = True, bq: int = 128, bk: int = 128,
              interpret: bool = True):
    """Model layout: q (B,Sq,H,d); k,v (B,Skv,KV,d) -> (B,Sq,H,d).

    Pads Sq/Skv to block multiples; pad keys are masked out by the causal
    test (pad kpos > every real qpos) so results are exact after slicing.
    """
    B, Sq, H, d = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_pallas:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
        return out.transpose(0, 2, 1, 3)
    qt, pq = _pad_to(qt, 2, bq)
    kt, pk = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    # padded q rows sit at positions > every key => fully-masked rows under
    # causal; harmless garbage rows get sliced off.  padded k rows sit at
    # kpos > qpos of all real rows => masked.  (causal=False with padding is
    # rejected: encoder attention goes through the ref path.)
    assert causal or (pq == 0 and pk == 0), "non-causal padding unsupported"
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          bq=bq, bk=bk, interpret=interpret)
    out = out[:, :, :Sq] if pq else out
    return out.transpose(0, 2, 1, 3)
