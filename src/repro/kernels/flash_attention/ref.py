"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q: (B,H,Sq,d); k,v: (B,KV,Skv,d).  Returns (B,H,Sq,d) in q.dtype."""
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    rep = H // KV
    scale = d ** -0.5 if scale is None else scale
    kx = jnp.repeat(k, rep, axis=1)
    vx = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(f32), kx.astype(f32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned positions
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    e = jnp.exp(s - m)
    w = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(vx.dtype), vx).astype(q.dtype)
