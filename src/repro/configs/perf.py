"""Performance knobs — the hillclimbing surface (EXPERIMENTS.md §Perf).

Everything here changes the compiled HLO but never the math (up to remat
recompute and grad-accumulation dtype).  Defaults are the paper-faithful
baseline; the perf loop flips them per-cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class PerfConfig:
    # attention
    q_chunk: int = 512
    attn_impl: str = "full"        # full | triangle (causal chunk skipping)
    # loss
    xent_chunk: int = 512
    # training memory
    remat: str = "full"            # none | full | dots
    microbatch: int = 1            # grad-accumulation steps over the global batch
    accum_dtype: str = "bfloat16"  # grad accumulator dtype (bfloat16 | float32)
    # sharding strategy (distributed/sharding.py rule-table variants)
    partitioning: str = "tp"       # tp | zero3 (layer-stack params over data)
    # kernels (real-TPU path; dry-run keeps XLA ref so cost_analysis sees flops)
    use_pallas: bool = False
    pallas_interpret: bool = True  # CPU validation; False on real TPU
    # kv cache dtype for decode shapes ("bfloat16" | "int8")
    kv_dtype: str = "bfloat16"
    # unroll the decode layer loop: a lax.scan DUS-updates the stacked KV
    # buffer every trip (XLA round-trips the whole stack through f32 —
    # measured 14.4 GB/step on qwen2 decode_32k); unrolling gives per-layer
    # cache tensors and in-place writes
    decode_unroll: bool = False
    # donate decode cache / train state buffers
    donate: bool = True


BASELINE = PerfConfig()


def with_overrides(perf: PerfConfig, **kw) -> PerfConfig:
    return dataclasses.replace(perf, **kw)
