"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    reduced,
    shape_supported,
)

_ARCH_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma-2b": "gemma_2b",
    "gemma3-27b": "gemma3_27b",
    "gemma3-4b": "gemma3_4b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "paligemma-3b": "paligemma_3b",
    "whisper-small": "whisper_small",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch.endswith("-smoke"):
        return reduced(get_config(arch[: -len("-smoke")]))
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def arch_shape_cells(include_skipped: bool = False):
    """All (arch, shape) cells; 40 total, with documented skips filtered."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = shape_supported(cfg, shape)
            if ok or include_skipped:
                yield arch, shape.name, ok, reason
