"""jamba-v0.1-52b [arXiv:2403.19887; hf ai21labs/Jamba-v0.1].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; Mamba:attn 7:1
(attention on layer 4 of each 8-layer Jamba block); MoE 16e top-2 every
2nd layer.  SSM: d_state=16, conv4, expand 2.

NOTE (DESIGN.md §2): Jamba uses Mamba-1 selective scan; we implement its SSM
layers with the Mamba-2/SSD formulation (multihead, scalar-per-head decay),
which the SSD paper shows is the hardware-efficient equivalent class.  State
size matches the published d_state=16.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    use_rope=False,  # Jamba attention has no positional encoding
    tie_embeddings=False,
    norm_eps=1e-6,
)
