"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072, headdim 64 -> 48 SSD heads, 1 group, conv4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    norm_eps=1e-5,
)
