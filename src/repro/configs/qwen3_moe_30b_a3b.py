"""qwen3-moe-30b-a3b [hf Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, head_dim=128, q/k RMSNorm) moe_d_ff=768
vocab=151936; 128 experts top-8 on every layer; no shared expert.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                 # every MLP is MoE
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    moe_every=1,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    norm_eps=1e-6,
)
