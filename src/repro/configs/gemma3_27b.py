"""gemma3-27b [hf google/gemma-3-27b-pt family].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; 5 local : 1 global
(window 1024, local rope theta 10k, global 1M); GeGLU; head_dim=128; 128k ctx.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    mlp_activation="gelu",
    local_ratio=5,
    local_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    scale_embed=True,
    qk_norm=True,
    norm_eps=1e-6,
)
