"""whisper-small [arXiv:2212.04356].

Enc-dec: 12L encoder + 12L decoder, d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865.  Conv frontend is a STUB (input_specs provides precomputed frame
embeddings, 1500 x d_model).  Learned positions, GELU MLP (non-gated).

NOTE (DESIGN.md §4): published max_target_positions is 448; the assigned
decode/prefill stress shapes size the decoder positional table to the
requested seq_len (backbone-only stress test per the brief).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    num_encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp_activation="gelu_plain",
    use_rope=False,
    is_encoder_decoder=True,
    encoder_seq=1500,
    tie_embeddings=True,
    norm_eps=1e-5,
    max_position=32768,
)
