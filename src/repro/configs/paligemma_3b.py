"""paligemma-3b [arXiv:2407.07726; hf google/paligemma-3b-pt-224].

SigLIP vision tower (STUB per brief: input_specs provides precomputed patch
embeddings, 256 tokens @ d_model) + gemma-2b text backbone, vocab=257216.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_activation="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embed=True,
    num_vision_tokens=256,
    norm_eps=1e-6,
)
