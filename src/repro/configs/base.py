"""Config system: architecture + input-shape registries.

Each assigned architecture contributes one module in this package exporting
``CONFIG`` (exact published dims) — see the per-arch files.  ``reduced()``
derives a structure-preserving tiny variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # attention flavour
    attn_bias: bool = False            # qwen2: bias on QKV
    qk_norm: bool = False              # qwen3: RMSNorm on q/k heads
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    use_rope: bool = True              # whisper uses learned positions
    sliding_window: int = 0            # uniform SWA (mixtral) — 0 = off
    local_window: int = 0              # gemma3 local-layer window
    local_ratio: int = 0               # gemma3: N local layers per 1 global
    mlp_activation: str = "silu"       # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    scale_embed: bool = False          # gemma family: embed * sqrt(d_model)
    max_position: int = 1_048_576      # rope archs: unbounded in practice

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1                 # MoE replaces MLP on layers i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (jamba): attention on layers i % attn_every == attn_offset; else SSM
    attn_every: int = 0
    attn_offset: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500            # whisper 30s @ 50Hz after conv stub

    # vlm (paligemma): prefix of precomputed patch embeddings
    num_vision_tokens: int = 0

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, i: int) -> str:
        """'attn' | 'attn_local' | 'ssm' for decoder layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_every:  # hybrid
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        if self.local_ratio:  # gemma3: pattern [local x N, global] repeating
            return "attn" if (i % (self.local_ratio + 1)) == self.local_ratio else "attn_local"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.num_experts:
            return False
        return i % self.moe_every == self.moe_offset

    def window_for(self, kind: str) -> int:
        """Effective attention window for a layer kind (0 = unbounded)."""
        if kind == "attn_local":
            return self.local_window
        return self.sliding_window

    # rough parameter counts (docs/roofline use exact spec counts instead)
    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        if self.family != "ssm":
            assert self.num_heads and self.head_dim
            if self.num_kv_heads:
                assert self.num_heads % self.num_kv_heads == 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / bounded-window attention).
_SUBQUADRATIC = {
    "mamba2-780m", "jamba-v0.1-52b", "mixtral-8x7b", "gemma3-27b", "gemma3-4b",
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not).  Skips are documented in DESIGN.md §4."""
    if shape.name == "long_500k" and cfg.name not in _SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode KV unbounded (DESIGN.md §4)"
    if cfg.is_encoder_decoder and shape.name == "long_500k":
        return False, "enc-dec decoder context architecturally capped"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Structure-preserving tiny variant for CPU smoke tests.

    Keeps: family, layer-kind pattern period, GQA ratio, MoE top-k, gating
    flavour.  Shrinks: widths, vocab, expert count, state dims.
    """
    # keep at least one full pattern period so hetero archs exercise all kinds
    period = 1
    if cfg.attn_every:
        period = cfg.attn_every
    elif cfg.local_ratio:
        period = cfg.local_ratio + 1
    if cfg.num_experts:
        period = max(period, 2 * cfg.moe_every)
    layers = max(2, period)

    n_heads = max(2, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    kv = 0
    if cfg.num_kv_heads:
        ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
        kv = max(1, n_heads // min(ratio, n_heads))
    n_exp = min(cfg.num_experts, 4) if cfg.num_experts else 0
    topk = min(cfg.experts_per_token, n_exp) if n_exp else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        d_model=64,
        num_heads=n_heads,
        num_kv_heads=kv,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=n_exp,
        experts_per_token=topk,
        moe_d_ff=96 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        local_window=min(cfg.local_window, 32) if cfg.local_window else 0,
        encoder_seq=24 if cfg.is_encoder_decoder else cfg.encoder_seq,
        num_vision_tokens=8 if cfg.num_vision_tokens else 0,
        max_position=4096,
    )


def model_flops_per_token(cfg: ModelConfig, n_params_active: int) -> float:
    """MODEL_FLOPS/token = 6*N_active (train) — roofline 'useful flops' basis."""
    return 6.0 * n_params_active
