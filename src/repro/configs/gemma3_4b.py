"""gemma3-4b [hf google/gemma-3-4b-pt family].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; 5:1 local:global
(window 1024); GeGLU; head_dim=256; 128k ctx.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    mlp_activation="gelu",
    local_ratio=5,
    local_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    scale_embed=True,
    qk_norm=True,
    norm_eps=1e-6,
)
