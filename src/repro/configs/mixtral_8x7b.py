"""mixtral-8x7b [arXiv:2401.04088; hf mistralai/Mixtral-8x7B-v0.1].

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=32000; 8 experts
top-2 on every layer; sliding-window attention 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                 # every MLP is MoE
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_every=1,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    norm_eps=1e-5,
)
