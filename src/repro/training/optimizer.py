"""AdamW with fp32 moments (ZeRO-1: moments are sharded over the data axis by
the distribution layer — see distributed/sharding.zero1_shardings)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import params as P

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def opt_state_specs(param_specs) -> dict:
    """Moment specs mirror param specs at fp32."""

    def mom(s: P.ParamSpec) -> P.ParamSpec:
        return dataclasses.replace(s, dtype=f32, init="zeros")

    return {
        "mu": P.tree_map_specs(mom, param_specs),
        "nu": P.tree_map_specs(mom, param_specs),
        "step": P.ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    }


def init_opt_state(param_specs):
    return P.init(jax.random.PRNGKey(0), opt_state_specs(param_specs))


def lr_at(cfg: AdamWConfig, step):
    s = step.astype(f32) + 1.0
    warm = s / max(cfg.warmup_steps, 1)
    return cfg.lr * jnp.minimum(warm, 1.0)


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, opt_state["step"])

    # global-norm clip
    gsq = sum(jnp.sum(jnp.square(g.astype(f32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(f32)
    bc2 = 1.0 - b2 ** step.astype(f32)

    def upd(p, g, mu, nu):
        g = g.astype(f32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * u).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
