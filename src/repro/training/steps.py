"""jit-able step functions: train_step / prefill_step / decode_step.

These are what the launcher lowers for the dry-run and what examples/tests
execute on CPU with reduced configs.

``make_train_step`` supports gradient accumulation (``perf.microbatch``):
the global batch is reshaped to (n_micro, mb, ...) and scanned, accumulating
grads in ``perf.accum_dtype``.  This is the standard memory lever for the
large train cells (activation bytes scale with mb, not global batch).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import layers as L
from repro.models.lm import make_model
from repro.training.optimizer import AdamWConfig, apply_updates

f32 = jnp.float32


def _split_micro(batch: dict, n: int, shd):
    """(B, ...) -> (n, B//n, ...) with batch kept on the data axis."""

    def one(name, x):
        mb = x.shape[0] // n
        y = x.reshape(n, mb, *x.shape[1:])
        names = (None, "batch") + ("act_seq",) * (y.ndim > 2) + (None,) * max(0, y.ndim - 3)
        return shd(y, names[: y.ndim])

    return {k: one(k, v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, perf: PerfConfig = BASELINE,
                    opt_cfg: AdamWConfig = AdamWConfig(), shd=L._noop_shd):
    model = make_model(cfg, perf)
    adt = jnp.dtype(perf.accum_dtype)
    from repro.models import params as P
    spec_leaves = jax.tree.leaves(model.param_specs(), is_leaf=P.is_spec)

    def loss_fn(p, batch):
        loss, metrics = model.loss(p, batch, shd)
        return loss, metrics

    def grad_fn(p, batch):
        out, grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        # pin grads to the *param* layout: under dp/zero3 rules this turns
        # the backward's full-size grad all-reduces into reduce-scatters
        # into the ZeRO shards (halves grad wire bytes)
        gl, tdef = jax.tree.flatten(grads)
        gl = [shd(g, s.axes) for g, s in zip(gl, spec_leaves)]
        return out, jax.tree.unflatten(tdef, gl)

    def train_step(params, opt_state, batch):
        if perf.microbatch > 1:
            micro = _split_micro(batch, perf.microbatch, shd)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                grads = jax.tree.map(lambda a, g: a + g.astype(adt), acc[0], grads)
                return (grads, acc[1] + loss, acc[2] + metrics["tokens"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum, tok), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), f32), jnp.zeros((), jnp.int32)), micro)
            inv = 1.0 / perf.microbatch
            grads = jax.tree.map(lambda g: (g.astype(f32) * inv).astype(g.dtype), gsum)
            loss = lsum * inv
            metrics = {"loss": loss, "tokens": tok}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            metrics = dict(metrics, loss=loss)
        params, opt_state, stats = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, **stats)
        return params, opt_state, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, perf: PerfConfig = BASELINE,
                      shd=L._noop_shd):
    model = make_model(cfg, perf)

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch, max_len, shd)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, perf: PerfConfig = BASELINE, shd=L._noop_shd):
    model = make_model(cfg, perf)

    def decode_step(params, tokens, pos, caches):
        logits, caches = model.decode_step(params, tokens, pos, caches, shd)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches

    return model, decode_step
