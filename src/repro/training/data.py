"""Synthetic-but-learnable data pipeline.

Tokens are drawn from a fixed random bigram chain (per seed), so models have
real structure to learn (loss drops well below uniform) while the pipeline
stays fully deterministic and resumable: batch i is a pure function of
(seed, i) — restart-safe without data-state checkpoints beyond the step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch: int = 8
    seq_len: int = 64
    seed: int = 17
    branching: int = 4          # candidate successors per token


class BigramStream:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        V = cfg.vocab_size
        # successor table (V, branching) + logits
        self.succ = rng.integers(0, V, size=(V, dcfg.branching), dtype=np.int64)
        self.probs = rng.dirichlet(np.ones(dcfg.branching), size=V).astype(np.float64)

    def batch(self, step: int) -> dict:
        d = self.dcfg
        rng = np.random.default_rng((d.seed, step))
        B, S, V = d.batch, d.seq_len, self.cfg.vocab_size
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        for t in range(1, S):
            cur = toks[:, t - 1]
            choice = np.array([rng.choice(d.branching, p=self.probs[c])
                               for c in cur])
            toks[:, t] = self.succ[cur, choice]
        out = {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(toks, jnp.int32)}
        if self.cfg.num_vision_tokens:
            out["patches"] = jnp.asarray(
                rng.normal(0, 0.02, (B, self.cfg.num_vision_tokens,
                                     self.cfg.d_model)), jnp.float32)
        if self.cfg.is_encoder_decoder:
            out["frames"] = jnp.asarray(
                rng.normal(0, 0.02, (B, self.cfg.encoder_seq, self.cfg.d_model)),
                jnp.float32)
        return out

    def uniform_nll(self) -> float:
        return float(np.log(self.cfg.vocab_size))
