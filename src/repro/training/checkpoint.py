"""Fault-tolerant checkpointing: atomic shard files, auto-resume, elastic
re-shard.

Layout:
    <dir>/step_000120/
        manifest.json      tree structure, shapes, dtypes, metadata
        shard_00000.npz    leaf arrays (path-keyed)
        COMMIT             written last — a checkpoint without it is garbage

Writes go to ``step_X.tmp`` and are atomically renamed after the COMMIT
marker is inside, so a crash mid-save can never corrupt the latest
checkpoint.  ``restore_latest`` skips uncommitted/corrupt directories.
On restore, arrays are ``device_put`` against the *current* mesh shardings
(elastic re-shard: the checkpoint is mesh-agnostic by construction).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SHARD_LEAVES = 1024  # leaves per shard file


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _paths(tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
         keep_last: int = 3) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flatten(tree)
    paths = _paths(tree)
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "leaves": [{"path": p, "shape": list(np.shape(l)),
                    "dtype": str(np.asarray(l).dtype)} for p, l in zip(paths, leaves)],
        "num_shards": (len(leaves) + _SHARD_LEAVES - 1) // max(_SHARD_LEAVES, 1),
    }
    for si in range(max(manifest["num_shards"], 1)):
        chunk = leaves[si * _SHARD_LEAVES: (si + 1) * _SHARD_LEAVES]
        names = [f"leaf_{si * _SHARD_LEAVES + i:06d}" for i in range(len(chunk))]
        arrs = {}
        for n, l in zip(names, chunk):
            a = np.asarray(jax.device_get(l))
            if a.dtype.name == "bfloat16":     # npz can't round-trip ml_dtypes
                a = a.view(np.uint16)
            arrs[n] = a
        np.savez(os.path.join(tmp, f"shard_{si:05d}.npz"), **arrs)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
                out.append(int(name[len("step_"):]))
    return sorted(out)


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-shards onto the
    current mesh — elastic across device-count changes."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = _flatten(like_tree)
    n = len(manifest["leaves"])
    arrs: list[np.ndarray | None] = [None] * n
    for si in range(max(manifest["num_shards"], 1)):
        with np.load(os.path.join(d, f"shard_{si:05d}.npz")) as z:
            for name in z.files:
                arrs[int(name[len("leaf_"):])] = z[name]
    assert all(a is not None for a in arrs), "missing leaves in checkpoint"
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * n)
    like_leaves = jax.tree.leaves(like_tree)
    out = []
    for a, sh, like, rec in zip(arrs, sh_leaves, like_leaves, manifest["leaves"]):
        if rec["dtype"] == "bfloat16" and a.dtype == np.uint16:
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        if hasattr(like, "dtype") and a.dtype != like.dtype:
            a = a.astype(like.dtype)
        out.append(jax.device_put(a, sh) if sh is not None else jax.numpy.asarray(a))
    return jax.tree.unflatten(treedef, out), manifest


def restore_latest(ckpt_dir: str, like_tree, shardings=None):
    steps = list_steps(ckpt_dir)
    if not steps:
        return None, None
    return restore(ckpt_dir, steps[-1], like_tree, shardings)


class AsyncSaver:
    """Background-thread checkpointing: training never blocks on I/O; the
    previous save is joined before the next begins (bounded memory)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, ckpt_dir: str, step: int, tree, metadata=None, keep_last=3):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, host_tree, metadata, keep_last),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
