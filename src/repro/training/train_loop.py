"""Training driver: auto-resume, periodic async checkpoints, failure hooks.

``Trainer.run`` is restart-idempotent: killing the process at any step and
re-running resumes from the last committed checkpoint and replays the
deterministic data stream from there — the integration test asserts the
loss trajectory is identical to an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import params as P
from repro.training import checkpoint as CKPT
from repro.training.data import BigramStream, DataConfig
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.steps import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    ckpt_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 dcfg: DataConfig = DataConfig(),
                 perf: PerfConfig = BASELINE,
                 opt: AdamWConfig = AdamWConfig(),
                 fail_at_step: int | None = None):
        self.cfg, self.tcfg, self.dcfg = cfg, tcfg, dcfg
        self.model, self._step_fn = make_train_step(cfg, perf, opt)
        self._jit = jax.jit(self._step_fn, donate_argnums=(0, 1))
        self.data = BigramStream(cfg, dcfg)
        self.saver = CKPT.AsyncSaver()
        self.fail_at_step = fail_at_step
        self.losses: list[float] = []

        specs = self.model.param_specs()
        self.params = P.init(jax.random.PRNGKey(tcfg.seed), specs)
        self.opt_state = init_opt_state(specs)
        self.start_step = 0
        restored, manifest = CKPT.restore_latest(
            tcfg.ckpt_dir, {"params": self.params, "opt": self.opt_state})
        if restored is not None:
            self.params, self.opt_state = restored["params"], restored["opt"]
            self.start_step = manifest["step"]

    def run(self, on_step: Callable[[int, dict], None] | None = None) -> list[float]:
        t0 = time.time()
        for step in range(self.start_step, self.tcfg.steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.saver.wait()
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.data.batch(step)
            self.params, self.opt_state, metrics = self._jit(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            self.losses.append(loss)
            if on_step:
                on_step(step, metrics)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.steps:
                tree = {"params": self.params, "opt": self.opt_state}
                meta = {"loss": loss, "wall_s": time.time() - t0}
                if self.tcfg.async_ckpt:
                    self.saver.save(self.tcfg.ckpt_dir, step + 1, tree, meta)
                else:
                    CKPT.save(self.tcfg.ckpt_dir, step + 1, tree, meta)
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"step {step+1}: loss {loss:.4f}", flush=True)
        self.saver.wait()
        return self.losses
