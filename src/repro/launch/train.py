"""Training launcher.

CPU (reduced config, real execution):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 50

Production (TPU pod, or dry-run compile check with --dryrun):
    python -m repro.launch.train --arch gemma3-27b --production \
        --perf partitioning=zero3 microbatch=1
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh (TPU pods); on "
                         "CPU this only makes sense with --dryrun")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the production train step and exit")
    ap.add_argument("--perf", nargs="*", default=[])
    args = ap.parse_args(argv)

    if args.production or args.dryrun:
        # defer to the dry-run machinery (sets device-count env first)
        from repro.launch import dryrun as DR
        rc = DR.main(["--arch", args.arch, "--shape", "train_4k",
                      "--mesh", "single"] +
                     (["--perf"] + args.perf if args.perf else []))
        return rc

    from repro.configs import get_config
    from repro.training.data import DataConfig
    from repro.training.train_loop import Trainer, TrainConfig
    cfg = get_config(args.arch + "-smoke")
    trainer = Trainer(cfg, TrainConfig(steps=args.steps,
                                       ckpt_every=args.ckpt_every,
                                       ckpt_dir=args.ckpt_dir, log_every=10),
                      DataConfig(batch=args.batch, seq_len=args.seq_len))
    if trainer.start_step:
        print(f"auto-resumed from step {trainer.start_step}")
    losses = trainer.run()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
