"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Target hardware: TPU v5e pods.  One pod = 16x16 = 256 chips; the multi-pod
configuration is 2 pods = 512 chips with the leading ``pod`` axis mapped to
DCN (inter-pod) links and ``data``/``model`` to intra-pod ICI.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 * 2**30        # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} present; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (launch/dryrun.py does)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
