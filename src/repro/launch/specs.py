"""ShapeDtypeStruct stand-ins for every model input (dry-run path).

No device allocation ever happens here; shapes are exact production shapes.
``decode`` cells lower ``serve_step`` (one new token against a cache sized to
shape.seq_len); ``train``/``prefill`` lower full sequences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import params as P
from repro.models.lm import make_model

i32 = jnp.int32
bf16 = jnp.bfloat16


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    text = S - (cfg.num_vision_tokens or 0)
    d = {"tokens": jax.ShapeDtypeStruct((B, text), i32)}
    if with_labels:
        d["labels"] = jax.ShapeDtypeStruct((B, text), i32)
    if cfg.num_vision_tokens:
        d["patches"] = jax.ShapeDtypeStruct((B, cfg.num_vision_tokens, cfg.d_model), bf16)
    if cfg.is_encoder_decoder:
        d["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), bf16)
    return d


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model=None, perf=None) -> dict:
    """tokens/pos/caches ShapeDtypeStructs for one decode step at context S."""
    B, S = shape.global_batch, shape.seq_len
    model = model or make_model(cfg, *( [perf] if perf else [] ))
    cache_specs = model.cache_specs(B, S)
    kv_dtype = jnp.dtype(perf.kv_dtype) if perf is not None else bf16

    def to_sds(s: P.ParamSpec):
        dt = kv_dtype if (s.dtype == bf16 and kv_dtype != bf16) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)

    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
        "caches": P.tree_map_specs(to_sds, cache_specs),
        "cache_param_specs": cache_specs,  # for sharding resolution
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None, perf=None) -> dict:
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    return decode_specs(cfg, shape, model, perf)
