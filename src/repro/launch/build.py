"""Cell builder: (arch x shape x mesh x perf) -> jitted fn + abstract args.

Shared by the dry-run, the roofline benchmark, and integration tests so the
lowered program is byte-identical across all three.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.distributed.sharding import Sharder, opt_sharding_tree, rules_for
from repro.launch import specs as SP
from repro.models import params as P
from repro.training import optimizer as OPT
from repro.training.steps import make_decode_step, make_prefill_step, make_train_step


def default_perf(cfg: ModelConfig, shape: ShapeConfig, base: PerfConfig = BASELINE) -> PerfConfig:
    """Napkin-math microbatch default: keep the per-device per-scan-step
    activation boundary (m * S * D * 2 / data) under ~128 MB."""
    perf = base
    if shape.kind == "train":
        data = 16
        budget = 128e6
        m_max = max(1, int(budget * data / (shape.seq_len * cfg.d_model * 2)))
        m = 1 << int(math.log2(m_max)) if m_max >= 1 else 1
        m = min(m, shape.global_batch)
        while shape.global_batch % m:
            m //= 2
        n_micro = shape.global_batch // m
        perf = dataclasses.replace(perf, microbatch=n_micro)
    return perf


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    perf: PerfConfig
    mesh: Any
    fn: Any                      # python step fn
    jitted: Any                  # jax.jit(fn, shardings...)
    abstract_args: tuple         # ShapeDtypeStructs to lower with
    model: Any
    sharder: Sharder


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, perf: PerfConfig | None = None) -> Cell:
    perf = perf if perf is not None else default_perf(cfg, shape)
    sharder = Sharder(mesh, rules_for(perf.partitioning)) if mesh is not None else Sharder(None)
    shd = sharder if mesh is not None else (lambda x, names: x)

    if shape.kind == "train":
        model, fn = make_train_step(cfg, perf, shd=shd)
        pspecs = model.param_specs()
        params_abs = P.abstract(pspecs)
        opt_abs = P.abstract(OPT.opt_state_specs(pspecs))
        batch_abs = SP.batch_specs(cfg, shape, with_labels=True)
        in_sh = None
        if mesh is not None:
            in_sh = (sharder.spec_shardings(pspecs),
                     opt_sharding_tree(sharder, pspecs),
                     sharder.batch_shardings(batch_abs))
        jitted = jax.jit(fn, in_shardings=in_sh,
                         donate_argnums=(0, 1) if perf.donate else ())
        return Cell(cfg, shape, perf, mesh, fn, jitted,
                    (params_abs, opt_abs, batch_abs), model, sharder)

    if shape.kind == "prefill":
        model, fn = make_prefill_step(cfg, shape.seq_len, perf, shd=shd)
        pspecs = model.param_specs()
        params_abs = P.abstract(pspecs)
        batch_abs = SP.batch_specs(cfg, shape, with_labels=False)
        in_sh = None
        if mesh is not None:
            in_sh = (sharder.spec_shardings(pspecs),
                     sharder.batch_shardings(batch_abs))
        jitted = jax.jit(fn, in_shardings=in_sh)
        return Cell(cfg, shape, perf, mesh, fn, jitted,
                    (params_abs, batch_abs), model, sharder)

    # decode
    model, fn = make_decode_step(cfg, perf, shd=shd)
    pspecs = model.param_specs()
    params_abs = P.abstract(pspecs)
    dspec = SP.decode_specs(cfg, shape, model, perf)
    in_sh = None
    if mesh is not None:
        tok_sh = NamedSharding(mesh, sharder.spec_for((shape.global_batch, 1), ("batch", None)))
        pos_sh = NamedSharding(mesh, sharder.spec_for((shape.global_batch,), ("batch",)))
        in_sh = (sharder.spec_shardings(pspecs), tok_sh, pos_sh,
                 sharder.spec_shardings(dspec["cache_param_specs"]))
    jitted = jax.jit(fn, in_shardings=in_sh,
                     donate_argnums=(3,) if perf.donate else ())
    return Cell(cfg, shape, perf, mesh, fn, jitted,
                (params_abs, dspec["tokens"], dspec["pos"], dspec["caches"]),
                model, sharder)


def lower_cell(cell: Cell):
    with (cell.mesh or jax.sharding.Mesh(jax.devices()[:1], ("_",))):
        return cell.jitted.lower(*cell.abstract_args)
