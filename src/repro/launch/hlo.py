"""Static analysis of optimized (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body **once** — a
scan-over-layers program under-reports flops/bytes by the trip count.  The
roofline needs the real numbers, so we parse the HLO module and walk it:

  * ``flops``  — 2 * prod(out) * contraction for every dot, recursing into
    fusions / called computations, and multiplying while bodies by their
    ``known_trip_count`` annotation.
  * ``bytes``  — HBM-traffic approximation: operand + output bytes of every
    top-level materializing op (fusions are single units — their internals
    live in registers/VMEM).  ``dynamic-update-slice``-rooted fusions count
    the updated slice, not the whole aliased buffer (in-place KV-cache
    writes would otherwise inflate decode bytes ~100x).
  * ``collectives`` — per-kind payload bytes with ring wire factors.

All shapes in post-partitioning HLO are **per-device**, so every number this
module returns is per-chip — exactly the roofline numerator.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# ops that define control/aliasing structure, not HBM traffic
_CONTROL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "custom-call", "after-all", "partition-id",
    "replica-id", "opt-barrier",
}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(tokens) -> int:
    return sum(
        _DTYPE_BYTES.get(dt, 4) * (eval("*".join(dims.split(",")) or "1") if dims else 1)
        for dt, dims in tokens)


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    op: str
    out_tokens: list          # [(dtype, dims), ...]
    operands: list[str]
    attrs: str
    args_raw: str = ""


def _parse_op(line: str) -> Op | None:
    m = _OP_LINE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2).strip()
    # split shape prefix from op
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape_str, tail = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_str, tail = rest[:sp], rest[sp:]
    om = re.match(r"\s*([\w\-]+)\(", tail)
    if not om:
        return None
    op = om.group(1)
    # operand names: inside the first balanced parens after the op name
    start = tail.index("(")
    depth, j = 0, start
    for j in range(start, len(tail)):
        depth += tail[j] == "("
        depth -= tail[j] == ")"
        if depth == 0:
            break
    args = tail[start + 1: j]
    operands = re.findall(r"%([^\s,()]+)", args)
    return Op(name, op, _SHAPE_TOKEN.findall(shape_str), operands, tail[j + 1:], args)


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[Op]] = {}
        self.entry: str | None = None
        cur: list[Op] | None = None
        for line in text.splitlines():
            if not line.strip():
                cur = None
                continue
            if not line.startswith((" ", "\t")):
                hm = _COMP_HDR.match(line)
                if hm:
                    cur = []
                    self.comps[hm.group(2)] = cur
                    if hm.group(1):
                        self.entry = hm.group(2)
                continue
            if cur is None:
                continue
            op = _parse_op(line)
            if op:
                cur.append(op)
        # symbol tables
        self.shapes: dict[str, dict[str, list]] = {
            c: {o.name: o.out_tokens for o in ops} for c, ops in self.comps.items()}

    # ------------------------------------------------------------- helpers
    def _trip(self, op: Op) -> int:
        m = _TRIP.search(op.attrs)
        return int(m.group(1)) if m else 1

    def _called(self, op: Op, key: str) -> str | None:
        m = re.search(key + r"=%?([\w\.\-]+)", op.attrs)
        return m.group(1) if m else None

    def _dot_flops(self, comp: str, op: Op) -> float:
        out_elems = sum(_nelems(d) for _, d in op.out_tokens)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        contraction = 1
        if m and op.operands:
            lhs_tokens = self.shapes[comp].get(op.operands[0])
            if lhs_tokens:
                dims = lhs_tokens[0][1].split(",") if lhs_tokens[0][1] else []
                for idx in (m.group(1).split(",") if m.group(1) else []):
                    i = int(idx)
                    if i < len(dims):
                        contraction *= int(dims[i])
        return 2.0 * out_elems * contraction

    # ------------------------------------------------------------- flops
    def flops(self, comp: str | None = None, _memo=None) -> float:
        comp = comp or self.entry
        _memo = _memo if _memo is not None else {}
        if comp in _memo:
            return _memo[comp]
        total = 0.0
        _memo[comp] = 0.0  # cycle guard
        for op in self.comps.get(comp, ()):
            if op.op == "dot":
                total += self._dot_flops(comp, op)
            elif op.op == "convolution":
                # rough: 2 * out_elems * kernel_elems (no grouping info)
                out_elems = sum(_nelems(d) for _, d in op.out_tokens)
                total += 2.0 * out_elems
            elif op.op == "fusion":
                c = self._called(op, "calls")
                if c:
                    total += self.flops(c, _memo)
            elif op.op == "while":
                body = self._called(op, "body")
                if body:
                    total += self._trip(op) * self.flops(body, _memo)
            elif op.op in ("call", "conditional", "async-start"):
                c = self._called(op, "to_apply") or self._called(op, "calls")
                if c:
                    total += self.flops(c, _memo)
        _memo[comp] = total
        return total

    # ------------------------------------------------------------- bytes
    _SLICE_LIKE = {"dynamic-slice", "gather", "slice"}

    def _fusion_param_reads(self, called: str) -> dict[int, float]:
        """Param index -> bytes actually read, for fusion params consumed by
        slice-like ops (a dynamic-slice of one layer's params from the
        [L, ...] scan stack reads the slice, not the stack)."""
        if not hasattr(self, "_fpr_memo"):
            self._fpr_memo: dict[str, dict[int, float]] = {}
        if called in self._fpr_memo:
            return self._fpr_memo[called]
        ops = self.comps.get(called, ())
        param_idx: dict[str, int] = {}
        alias: dict[str, str] = {}          # bitcast/reshape name -> source
        for o in ops:
            if o.op in ("bitcast", "reshape", "copy", "convert") and o.operands:
                alias[o.name] = o.operands[0]
        # parameter(N): N sits in the args region (fused computations print
        # params in topological order, NOT index order)
        for o in ops:
            if o.op == "parameter" and o.args_raw.strip().isdigit():
                param_idx[o.name] = int(o.args_raw.strip())
        reads: dict[int, float] = {}
        consumed_elsewhere: dict[int, bool] = {}
        for o in ops:
            if o.op in ("parameter", "bitcast", "reshape"):
                continue
            for pos, src in enumerate(o.operands):
                seen = set()
                while src in alias and src not in seen:
                    seen.add(src)
                    src = alias[src]
                if src not in param_idx:
                    continue
                i = param_idx[src]
                if o.op in self._SLICE_LIKE and pos == 0:
                    reads[i] = reads.get(i, 0.0) + _shape_bytes(o.out_tokens)
                else:
                    consumed_elsewhere[i] = True
        # a param also read at full shape elsewhere: fall back to full size
        out = {i: b for i, b in reads.items() if not consumed_elsewhere.get(i)}
        self._fpr_memo[called] = out
        return out

    def _op_bytes(self, comp: str, op: Op) -> float:
        table = self.shapes[comp]
        out_b = _shape_bytes(op.out_tokens)
        if op.op in self._SLICE_LIKE:
            return 2.0 * out_b                      # read slice + write slice
        if op.op == "dynamic-update-slice":
            upd = table.get(op.operands[1], ()) if len(op.operands) > 1 else ()
            return 2.0 * _shape_bytes(upd)          # in-place slice write
        if op.op in ("broadcast", "iota"):
            return out_b
        in_b = sum(_shape_bytes(table.get(o, ())) for o in op.operands)
        if op.op == "fusion":
            c = self._called(op, "calls")
            if c:
                # slice-consumed params: count the slice, not the buffer
                sliced = self._fusion_param_reads(c)
                for i, o in enumerate(op.operands):
                    if i in sliced:
                        in_b -= _shape_bytes(table.get(o, ()))
                        in_b += sliced[i]
                # in-place dynamic-update-slice root: slice write + drop the
                # aliased big operand from the read side
                for inner in self.comps.get(c, ()):
                    if inner.op == "dynamic-update-slice" and \
                            _shape_bytes(inner.out_tokens) == out_b:
                        upd = self.shapes[c].get(inner.operands[1], ()) \
                            if len(inner.operands) > 1 else ()
                        return max(0.0, in_b - out_b) + 2.0 * _shape_bytes(upd)
        return in_b + out_b

    def bytes_accessed(self, comp: str | None = None, _memo=None) -> float:
        comp = comp or self.entry
        _memo = _memo if _memo is not None else {}
        if comp in _memo:
            return _memo[comp]
        total = 0.0
        _memo[comp] = 0.0
        for op in self.comps.get(comp, ()):
            if op.op == "while":
                body, cond = self._called(op, "body"), self._called(op, "condition")
                t = self._trip(op)
                if body:
                    total += t * self.bytes_accessed(body, _memo)
                if cond:
                    total += t * self.bytes_accessed(cond, _memo)
            elif op.op in ("call", "conditional"):
                c = self._called(op, "to_apply") or self._called(op, "calls")
                if c:
                    total += self.bytes_accessed(c, _memo)
            elif op.op in _CONTROL or op.op.startswith(COLLECTIVE_OPS):
                continue
            else:
                total += self._op_bytes(comp, op)
        _memo[comp] = total
        return total

    # ------------------------------------------------------------- comms
    def collectives(self, comp: str | None = None, mult: float = 1.0,
                    acc=None) -> dict:
        """Per-kind *wire* bytes per device (ring factors applied)."""
        comp = comp or self.entry
        acc = acc if acc is not None else defaultdict(float)
        for op in self.comps.get(comp, ()):
            base = op.op.replace("-start", "")
            if base in COLLECTIVE_OPS:
                out_b = _shape_bytes(op.out_tokens)
                g = None
                m = _GROUPS.search(op.attrs)
                if m:
                    g = int(m.group(2))
                else:
                    m2 = _GROUPS_OLD.search(op.attrs)
                    if m2:
                        g = len(m2.group(1).split(","))
                g = g or 2
                if base == "all-reduce":
                    wire = 2.0 * out_b * (g - 1) / g
                elif base == "all-gather":
                    wire = out_b * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif base == "all-to-all":
                    wire = out_b * (g - 1) / g
                else:  # permute / broadcast
                    wire = out_b
                acc[base] += mult * wire
                acc[base + "_payload"] += mult * out_b
                acc["count"] += mult
            elif op.op == "while":
                body = self._called(op, "body")
                if body:
                    self.collectives(body, mult * self._trip(op), acc)
            elif op.op == "fusion":
                pass  # collectives never live inside fusions
            elif op.op in ("call", "conditional"):
                c = self._called(op, "to_apply") or self._called(op, "calls")
                if c:
                    self.collectives(c, mult, acc)
        acc["total"] = sum(v for k, v in acc.items() if k in COLLECTIVE_OPS)
        return dict(acc)


def top_ops(mod: "HloModule", what: str = "bytes", k: int = 15) -> list:
    """Largest contributors (with while-trip multipliers) for perf debugging.
    what: 'bytes' | 'collectives'."""
    acc: dict = defaultdict(float)

    def walk(comp: str, mult: float):
        for op in mod.comps.get(comp, ()):
            if op.op == "while":
                b, c = mod._called(op, "body"), mod._called(op, "condition")
                t = mod._trip(op)
                if b:
                    walk(b, mult * t)
                if c:
                    walk(c, mult * t)
            elif op.op in ("call", "conditional"):
                c = mod._called(op, "to_apply") or mod._called(op, "calls")
                if c:
                    walk(c, mult)
            elif op.op in _CONTROL:
                continue
            elif op.op.replace("-start", "") in COLLECTIVE_OPS:
                if what == "collectives":
                    acc[(comp[-30:], op.op, op.name[:48])] += \
                        mult * _shape_bytes(op.out_tokens)
            elif what == "bytes":
                acc[(comp[-30:], op.op, op.name[:48])] += \
                    mult * mod._op_bytes(comp, op)

    walk(mod.entry, 1.0)
    return sorted(acc.items(), key=lambda kv: -kv[1])[:k]


# --------------------------------------------------------------------- API
def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    return {
        "flops_per_device": mod.flops(),
        "bytes_per_device": mod.bytes_accessed(),
        "collectives_per_device": mod.collectives(),
    }


def flops_bytes(compiled) -> tuple[float, float]:
    """XLA's own entry-level numbers (while bodies counted once) — reported
    alongside the walker numbers for comparison."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def memory_per_device(compiled) -> dict:
    ma = compiled.memory_analysis()
    arg = int(getattr(ma, "argument_size_in_bytes", 0))
    out = int(getattr(ma, "output_size_in_bytes", 0))
    tmp = int(getattr(ma, "temp_size_in_bytes", 0))
    alias = int(getattr(ma, "alias_size_in_bytes", 0))
    peak = int(getattr(ma, "peak_memory_in_bytes", 0))
    return {
        "argument_bytes": arg, "output_bytes": out, "temp_bytes": tmp,
        "alias_bytes": alias,
        "peak_bytes": peak if peak else (arg + out + tmp - alias),
    }
