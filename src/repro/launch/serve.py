"""Serving launcher: cloud-native orchestrated engines (reduced, CPU) or
production-mesh serve-step dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 12
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --dryrun
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-replicas", type=int, default=3)
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the production decode step and exit")
    ap.add_argument("--perf", nargs="*", default=[])
    args = ap.parse_args(argv)

    if args.dryrun:
        from repro.launch import dryrun as DR
        return DR.main(["--arch", args.arch, "--shape", "decode_32k",
                        "--mesh", "single"] +
                       (["--perf"] + args.perf if args.perf else []))

    from repro.configs import get_config
    from repro.core.autoscaler import HPAConfig
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.serving import InferenceEngine, Request, SamplingParams

    cfg = get_config(args.arch + "-smoke")
    orch = Orchestrator(
        lambda: InferenceEngine(cfg, capacity=args.capacity, max_len=64,
                                buckets=(8, 16), seed=7),
        OrchestratorConfig(hpa=HPAConfig(metric="queue", target=3.0,
                                         max_replicas=args.max_replicas,
                                         tolerance=0.0, stabilization_s=2.0)))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        orch.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 14)))],
            sampling=SamplingParams(max_new_tokens=6, temperature=0.7,
                                    top_k=40)))
    done = orch.run(max_steps=800)
    print(f"served {len(done)}/{args.requests} requests on "
          f"{len(orch.engines)} replicas "
          f"({len(orch.migrations.events)} migrations)")
    for r in done[:4]:
        print(f"  rid={r.rid} ttft={r.ttft:.2f}s tokens={len(r.output)}")
    return 0 if len(done) == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
