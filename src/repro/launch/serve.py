"""Serving launcher: cloud-native orchestrated engines (reduced, CPU) or
production-mesh serve-step dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 12
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --stream
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --dryrun

``--stream`` serves through the OpenAI-style completions front-end
(serving/api.py) and prints SSE frames as tokens are emitted — per-token
streaming over the cluster, migrations included.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _build_registry(args, cfg):
    """Both serve paths run through one EndpointRegistry — single-model
    serving is simply a one-endpoint registry (the bare ``Orchestrator``
    constructor still works for library callers)."""
    from repro.core.autoscaler import HPAConfig
    from repro.core.endpoints import EndpointRegistry, ModelEndpoint

    return EndpointRegistry([ModelEndpoint(
        name=args.arch, model=cfg, capacity=args.capacity,
        max_replicas=args.max_replicas, cold_start_steps=0,
        hpa=HPAConfig(metric="queue", target=3.0,
                      max_replicas=args.max_replicas,
                      tolerance=0.0, stabilization_s=2.0))])


def _print_models(registry) -> None:
    """The /v1/models surface, as the service banner."""
    from repro.serving import ModelsAPI

    for m in ModelsAPI(registry).list().data:
        print(f"model {m.id}: state={m.state} replicas={m.replicas} "
              f"priority={m.priority}")


def _report(done, rejected, total, n_replicas, n_migrations) -> bool:
    """Success = every request accounted for; REJECTED requests are an
    explicit outcome reported on their own line, never silently folded
    into the served count."""
    print(f"served {len(done)}/{total} requests on {n_replicas} replicas "
          f"({n_migrations} migrations)")
    if rejected:
        print(f"rejected {len(rejected)}/{total} requests "
              f"(rids: {sorted(r.rid for r in rejected)})")
    for r in done[:4]:
        print(f"  rid={r.rid} ttft={r.ttft:.2f}s tokens={len(r.output)} "
              f"finish={r.finish_reason}")
    return len(done) + len(rejected) == total


def _serve_batch(args, cfg, registry) -> int:
    from repro.serving import Request, SamplingParams, State

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        reqs.append(Request(
            rid=i, model=args.arch,
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 14)))],
            sampling=SamplingParams(max_new_tokens=6, temperature=0.7,
                                    top_k=40)))
        registry.submit(reqs[-1])
    done = registry.run(max_steps=800)
    rejected = [r for r in reqs if r.state is State.REJECTED]
    orch = registry.resolve(args.arch)
    ok = _report(done, rejected, args.requests, registry.total_replicas(),
                 len(orch.migrations.events))
    return 0 if ok else 1


def _serve_stream(args, cfg, registry) -> int:
    """Per-token streaming demo: interleaved SSE streams over the cluster
    front-end, printed as frames arrive."""
    from repro.serving import SSE_DONE, CompletionRequest, CompletionsAPI

    api = CompletionsAPI(registry, model=args.arch)
    rng = np.random.default_rng(0)
    n = min(args.requests, 4)        # a readable number of live streams
    gens = []
    for _ in range(n):
        creq = CompletionRequest(
            prompt=[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                 int(rng.integers(4, 14)))],
            model=args.arch, max_tokens=6, temperature=0.7, top_k=40,
            stream=True)
        gens.append(api.stream(creq, now=0.0))
    live, finished = list(gens), 0
    while live:                      # round-robin: frames interleave
        for g in list(live):
            try:
                chunk = next(g)
            except StopIteration:
                live.remove(g)
                continue
            sys.stdout.write(chunk.to_sse())
            if chunk.choices[0]["finish_reason"] is not None:
                finished += 1 if chunk.choices[0]["finish_reason"] != \
                    "rejected" else 0
                sys.stdout.write(SSE_DONE)
    print(f"streamed {finished}/{n} requests to completion on "
          f"{registry.total_replicas()} replicas")
    return 0 if finished == n else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-replicas", type=int, default=3)
    ap.add_argument("--stream", action="store_true",
                    help="serve through the completions front-end and print "
                         "per-token SSE frames")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the production decode step and exit")
    ap.add_argument("--trace-out", default=None,
                    help="write the request-lifecycle trace as Chrome/"
                         "Perfetto trace-event JSON to this path")
    ap.add_argument("--metrics-out", default=None,
                    help="write a Prometheus text exposition of the cluster "
                         "metrics registry to this path")
    ap.add_argument("--perf", nargs="*", default=[])
    args = ap.parse_args(argv)

    if args.dryrun:
        from repro.launch import dryrun as DR
        return DR.main(["--arch", args.arch, "--shape", "decode_32k",
                        "--mesh", "single"] +
                       (["--perf"] + args.perf if args.perf else []))

    from repro.configs import get_config
    cfg = get_config(args.arch + "-smoke")
    registry = _build_registry(args, cfg)
    _print_models(registry)
    rc = _serve_stream(args, cfg, registry) if args.stream \
        else _serve_batch(args, cfg, registry)
    _print_models(registry)
    if args.trace_out:
        registry.tracer.write_chrome_trace(args.trace_out)
        print(f"trace written to {args.trace_out} "
              f"({sum(1 for _ in registry.tracer.traces())} traces)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(registry.metrics.render())
        print(f"metrics exposition written to {args.metrics_out}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
