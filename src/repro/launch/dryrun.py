import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count on first init.

# Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.
#
# For each cell we record (to stdout and --out JSONL):
#   * memory_analysis()  — per-device bytes: proves the cell fits 16 GiB HBM
#   * cost_analysis()    — HLO flops / bytes accessed (roofline numerators)
#   * collective bytes   — parsed from the SPMD-partitioned HLO text
#   * lower/compile wall time
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
#   python -m repro.launch.dryrun --all --mesh both --out benchmarks/out/dryrun.jsonl

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_supported
from repro.configs.perf import PerfConfig, with_overrides
from repro.launch import hlo as H
from repro.launch.build import build_cell, default_perf
from repro.launch.mesh import HBM_BYTES, make_production_mesh


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             perf: PerfConfig | None = None, *, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, perf)
        rec["perf"] = {k: getattr(cell.perf, k) for k in
                       ("microbatch", "remat", "attn_impl", "q_chunk",
                        "partitioning", "kv_dtype", "accum_dtype")}
        with mesh:
            lowered = cell.jitted.lower(*cell.abstract_args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = H.memory_per_device(compiled)
        xla_flops, xla_bytes = H.flops_bytes(compiled)
        walk = H.analyze(compiled.as_text())
        rec.update(status="ok", memory=mem,
                   flops_per_device=walk["flops_per_device"],
                   bytes_per_device=walk["bytes_per_device"],
                   collectives=walk["collectives_per_device"],
                   xla_flops=xla_flops, xla_bytes=xla_bytes,
                   fits_hbm=bool(mem["peak_bytes"] <= HBM_BYTES))
        if verbose:
            coll = walk["collectives_per_device"]
            print(f"[{mesh_name}] {arch} x {shape_name}: OK  "
                  f"peak={mem['peak_bytes']/2**30:.2f}GiB "
                  f"flops/dev={walk['flops_per_device']:.3e} "
                  f"bytes/dev={walk['bytes_per_device']:.3e} "
                  f"coll/dev={coll.get('total',0):.3e}B "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
    except Exception as e:  # a failure here is a bug in our sharding config
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{mesh_name}] {arch} x {shape_name}: FAIL {type(e).__name__}: {e}",
                  flush=True)
    return rec


def parse_perf_overrides(pairs: list[str]) -> dict:
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        fields = PerfConfig.__dataclass_fields__
        typ = fields[k].type
        if typ in ("int",):
            v = int(v)
        elif typ in ("bool",):
            v = v.lower() in ("1", "true", "yes")
        out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--perf", nargs="*", default=None, help="k=v PerfConfig overrides")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape in (None, "all")) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    overrides = parse_perf_overrides(args.perf)
    records, failed = [], 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape_name in shapes:
                perf = None
                if overrides:
                    perf = with_overrides(
                        default_perf(get_config(arch), SHAPES[shape_name]), **overrides)
                rec = run_cell(arch, shape_name, mesh, mesh_name, perf)
                records.append(rec)
                failed += rec["status"] == "fail"
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    okc = sum(r["status"] == "ok" for r in records)
    skipc = sum(r["status"] == "skip" for r in records)
    print(f"\ndry-run: {okc} ok, {skipc} documented skips, {failed} failures", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
