"""Parameter-spec machinery.

Every model in the zoo declares its parameters as a pytree of
:class:`ParamSpec` leaves.  From the spec tree we can derive, without ever
materialising a weight:

* ``abstract(specs)``  -> ShapeDtypeStruct tree (for ``jit.lower`` dry-runs)
* ``logical_axes(specs)`` -> logical-axis-name tree (for sharding rules)
* ``init(key, specs)`` -> real arrays (for CPU smoke tests / tiny training)

Repeated layer groups are expressed by :func:`stack` which prepends a
``"layers"`` axis, matching ``jax.lax.scan``-over-layers execution.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary.  distributed/sharding.py maps these to mesh axes.
#   layers   - stacked scan axis (never sharded)
#   embed    - d_model
#   mlp      - feed-forward hidden
#   heads    - query heads * head_dim fused or head axis
#   kv_heads - key/value head axis
#   qkv      - per-head feature dim
#   vocab    - vocabulary
#   experts  - MoE expert axis
#   conv     - short conv taps
#   state    - SSM state dim
#   norm     - norm scales (replicated)
#   pos      - positional table


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | const
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def stack(specs, n: int):
    """Prepend a stacked ``layers`` axis of size n to every spec."""

    def _stack(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n, *s.shape), axes=("layers", *s.axes))

    return tree_map_specs(_stack, specs)


def abstract(specs):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def logical_axes(specs):
    return tree_map_specs(lambda s: s.axes, specs)


def _path_seed(path) -> int:
    name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "big")


def init(key, specs):
    """Materialise real arrays.  Deterministic per-leaf (path-derived keys)."""

    def _init(path, s: ParamSpec):
        k = jax.random.fold_in(key, _path_seed(path))
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "const":
            return jnp.full(s.shape, s.scale, s.dtype)
        if s.init == "embed":
            std = s.scale
            return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)
        if s.init == "normal":
            return (jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(s.dtype)
        # fan_in: truncated-normal-ish with 1/sqrt(fan_in); fan_in = second-to-last
        # dim for matrices (stacked axes excluded), last dim for vectors.
        shape = s.shape
        # drop leading stacked axes when computing fan-in
        core = [d for d, a in zip(shape, s.axes) if a != "layers"]
        fan_in = core[-2] if len(core) >= 2 else core[-1]
        std = s.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)

    return jax.tree_util.tree_map_with_path(_init, specs, is_leaf=is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def count_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves))
