"""Composable functional layers shared by the model zoo.

Conventions
-----------
* Every module is a (``*_specs`` -> ParamSpec tree, ``*_apply`` -> arrays) pair.
* Activations are bf16; softmax/logsumexp/norm statistics and SSM states fp32.
* ``shd(x, names)`` is a sharding hook (see distributed/sharding.Sharder);
  models call it on key activations, a no-op outside a mesh context.
* Attention is chunked over query blocks (python-unrolled; the loop lives
  inside the scan-over-layers body, so HLO stays O(chunks), not O(layers)).
  - impl="full":    every q-chunk attends the whole kv (baseline; 2x causal flops)
  - impl="triangle": q-chunk i attends kv[0:(i+1)*cq] (true causal flops)
  - windowed layers always use static banded kv slices.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

f32 = jnp.float32
bf16 = jnp.bfloat16


def _noop_shd(x, names):
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("norm",), dtype=f32, init="zeros")}


def rmsnorm(p, x, eps: float, *, plus_one: bool = True):
    """RMSNorm with (1 + scale) parameterisation (gemma/llama-compatible:
    scale initialised at zero == identity scale of one)."""
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = p["scale"] + 1.0 if plus_one else p["scale"]
    return (y * w).astype(x.dtype)


def layernorm_specs(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), ("norm",), dtype=f32, init="ones"),
        "bias": ParamSpec((d,), ("norm",), dtype=f32, init="zeros"),
    }


def layernorm(p, x, eps: float):
    xf = x.astype(f32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embedding (half-rotation / NeoX style)
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    ang = positions[..., None].astype(f32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(f32), x[..., half:].astype(f32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, *, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "qkv")),
        "wk": ParamSpec((D, KV, hd), ("embed", "kv_heads", "qkv")),
        "wv": ParamSpec((D, KV, hd), ("embed", "kv_heads", "qkv")),
        "wo": ParamSpec((H, hd, D), ("heads", "qkv", "embed")),
    }
    if cfg.attn_bias and not cross:
        specs["bq"] = ParamSpec((H, hd), ("heads", "qkv"), init="zeros")
        specs["bk"] = ParamSpec((KV, hd), ("kv_heads", "qkv"), init="zeros")
        specs["bv"] = ParamSpec((KV, hd), ("kv_heads", "qkv"), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = rmsnorm_specs(hd)
        specs["k_norm"] = rmsnorm_specs(hd)
    return specs


def _project_qkv(p, x, cfg: ModelConfig, positions, theta: float, *, with_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if with_rope and cfg.use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def _mha_chunk(q, k, v, mask, scale):
    """One (q-chunk, kv-slab) attention with full-row softmax.

    q: (B,cq,H,d)  k,v: (B,sk,KV,d)  mask: (B or 1, cq, sk) bool or None.

    Masking is additive on the small (cq, sk) bias, never a where() on the
    (B,KV,rep,cq,sk) scores: XLA would materialize (and loop-hoist) the full
    broadcast pred buffer, which at 4k train shapes is GiB-scale per device.
    """
    B, cq, H, d = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, cq, KV, rep, d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k, preferred_element_type=f32)
    scores = scores * scale
    if mask is not None:
        bias = jnp.where(mask, 0.0, -1e30).astype(f32)  # (B|1, cq, sk)
        scores = scores + bias[:, None, None, :, :]
    m = jnp.max(scores, axis=-1, keepdims=True)
    # guard fully-masked rows
    m = jnp.maximum(m, -1e29)
    e = jnp.exp(scores - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    w = (e / jnp.maximum(s, 1e-30)).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v)
    return out.reshape(B, cq, H, d)


def attention_full(
    q, k, v, *,
    causal: bool,
    window: int = 0,
    prefix_len: int = 0,
    q_chunk: int = 512,
    impl: str = "triangle",
    scale: float | None = None,
):
    """Chunked attention over full sequences (train / prefill).

    q: (B,Sq,H,d); k,v: (B,Skv,KV,d).  Assumes q positions == kv positions
    (self-attention) when causal; cross-attention passes causal=False.
    """
    B, Sq, H, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    cq = min(q_chunk, Sq)
    n = math.ceil(Sq / cq)
    outs = []
    for i in range(n):
        q0, q1 = i * cq, min((i + 1) * cq, Sq)
        qi = q[:, q0:q1]
        if not causal:
            ki, k0 = k, 0
            vi = v
        elif window:
            k0 = max(0, q1 - window - (q1 - q0))
            ki, vi = k[:, k0:q1], v[:, k0:q1]
        elif impl == "triangle":
            k0 = 0
            ki, vi = k[:, :q1], v[:, :q1]
        else:  # full kv slab (baseline)
            k0 = 0
            ki, vi = k, v
        mask = None
        if causal:
            qpos = jnp.arange(q0, q1)[:, None]
            kpos = jnp.arange(k0, k0 + ki.shape[1])[None, :]
            m = kpos <= qpos
            if window:
                m &= kpos > qpos - window
            if prefix_len:
                m |= (qpos < prefix_len) & (kpos < prefix_len)
            mask = m[None]
        outs.append(_mha_chunk(qi, ki, vi, mask, scale))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def attention_decode(q, k_cache, v_cache, kv_mask, scale: float | None = None):
    """Single-step decode attention.

    q: (B,1,H,d); caches: (B,S,KV,d); kv_mask: (B,S) bool valid slots.
    """
    B, _, H, d = q.shape
    KV = k_cache.shape[2]
    rep = H // KV
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(B, KV, rep, d)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache, preferred_element_type=f32)
    scores = scores * scale
    scores = jnp.where(kv_mask[:, None, None, :], scores, -1e30)
    m = jnp.maximum(jnp.max(scores, axis=-1, keepdims=True), -1e29)
    e = jnp.exp(scores - m)
    w = (e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)).astype(v_cache.dtype)
    out = jnp.einsum("bgrs,bsgd->bgrd", w, v_cache)
    return out.reshape(B, 1, H, d)


def attn_out(p, ctx):
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# ---------------------------------------------------------------------------
# KV caches: global (absolute slots) and ring (windowed layers)
# ---------------------------------------------------------------------------

def kv_cache_specs(cfg: ModelConfig, batch: int, length: int, *, ring: bool) -> dict:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    d = {
        "k": ParamSpec((batch, length, KV, hd), ("batch", "act_kv", "kv_heads", "qkv"), init="zeros"),
        "v": ParamSpec((batch, length, KV, hd), ("batch", "act_kv", "kv_heads", "qkv"), init="zeros"),
    }
    if ring:
        # absolute position held in each ring slot (-1 = empty)
        d["pos"] = ParamSpec((batch, length), ("batch", "act_kv"), dtype=jnp.int32, init="const", scale=-1)
    return d


def cache_write_prefill(cache, k, v, *, ring: bool, window: int, true_len=None):
    """Write a full prefill's k/v into a cache whose length may exceed S
    (global) or be the window W (ring).  Positions are 0..S-1.

    ``true_len`` (B,) int32 supports right-padded prompts (bucketed prefill):
    * global caches need no masking — pad slots sit at positions >= true_len
      and decode overwrites slot p exactly when position p becomes visible;
    * ring caches store explicit slot positions, so the last W *valid* tokens
      are gathered per-row and pad slots are marked -1 (invisible).
    """
    B, S = k.shape[:2]
    L = cache["k"].shape[1]
    if not ring:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        return {"k": ck, "v": cv}
    if true_len is None:
        # keep last min(S, L) tokens, slot = pos % L
        take = min(S, L)
        kt, vt = k[:, S - take:], v[:, S - take:]
        pos = jnp.arange(S - take, S, dtype=jnp.int32)
        slots = pos % L
        ck = cache["k"].at[:, slots].set(kt.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(vt.astype(cache["v"].dtype))
        cpos = cache["pos"].at[:, slots].set(jnp.broadcast_to(pos, (B, take)))
        return {"k": ck, "v": cv, "pos": cpos}
    # per-row window [true_len - L, true_len) gathered to canonical slots
    idx = true_len[:, None] - L + jnp.arange(L, dtype=jnp.int32)[None, :]  # (B,L)
    valid = idx >= 0
    safe = jnp.maximum(idx, 0)
    gk = jnp.take_along_axis(k, safe[:, :, None, None], axis=1)
    gv = jnp.take_along_axis(v, safe[:, :, None, None], axis=1)
    slots = safe % L
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    ck = cache["k"].at[rows, slots].set(gk.astype(cache["k"].dtype))
    cv = cache["v"].at[rows, slots].set(gv.astype(cache["v"].dtype))
    cpos = cache["pos"].at[rows, slots].set(jnp.where(valid, idx, -1))
    return {"k": ck, "v": cv, "pos": cpos}


def cache_write_chunk(cache, k, v, pos0, n_valid, *, ring: bool):
    """Append a chunk of C tokens at per-row absolute positions
    pos0 .. pos0+n_valid-1 into a slot cache (slot = pos % L).

    k/v: (B,C,KV,hd) right-padded chunk projections; pos0/n_valid (B,) int32.
    Rows with n_valid == 0 are untouched (batched chunked prefill runs the
    whole pool through one program; idle rows must be exact no-ops).  When
    the chunk is longer than a ring cache the *latest* token that maps to
    each slot wins, matching sequential decode-write semantics.

    Like cache_write_decode this is a gather + select, not a scatter (XLA:CPU
    expands bf16 scatters through a full-buffer f32 promote/demote).
    """
    B, C = k.shape[:2]
    L = cache["k"].shape[1]
    end1 = pos0 + n_valid - 1                            # (B,) last valid pos
    s = jnp.arange(L, dtype=jnp.int32)[None, :]          # (1,L) slot index
    p = end1[:, None] - ((end1[:, None] - s) % L)        # latest pos ≡ s (mod L)
    valid = (p >= pos0[:, None]) & (n_valid[:, None] > 0)
    j = jnp.clip(p - pos0[:, None], 0, C - 1)            # (B,L) chunk index
    gk = jnp.take_along_axis(k, j[:, :, None, None], axis=1)
    gv = jnp.take_along_axis(v, j[:, :, None, None], axis=1)
    m = valid[:, :, None, None]
    ck = jnp.where(m, gk.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(m, gv.astype(cache["v"].dtype), cache["v"])
    out = {"k": ck, "v": cv}
    if ring:
        out["pos"] = jnp.where(valid, p, cache["pos"])
    return out


def attention_chunk(q, k, v, cache, pos0, *, window: int, ring: bool,
                    scale: float | None = None):
    """Chunked-prefill attention: queries at positions pos0+i attend the
    cache as written by *previous* chunks (positions < pos0) plus this
    chunk's own k/v causally.

    q: (B,C,H,d); k/v: (B,C,KV,d) this chunk's projections (pre-write);
    cache: the cache *before* this chunk's write.  Sourcing the current
    chunk from k/v rather than the written cache keeps windowed (ring)
    layers exact even when the chunk is longer than the ring (where the
    write would overwrite slots early queries still need).
    """
    B, C, H, d = q.shape
    L = cache["k"].shape[1]
    qpos = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B,C)
    if ring:
        sp = cache["pos"]                                            # (B,L)
        mc = (sp >= 0) & (sp < pos0[:, None])
    else:
        sp = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
        mc = sp < pos0[:, None]
    mc = mc[:, None, :] & (sp[:, None, :] <= qpos[:, :, None])       # (B,C,L)
    if window:
        mc &= sp[:, None, :] > qpos[:, :, None] - window
    i = jnp.arange(C, dtype=jnp.int32)
    mx = i[None, :] <= i[:, None]                                    # (C,C) causal
    if window:
        mx &= i[None, :] > i[:, None] - window
    mask = jnp.concatenate(
        [mc, jnp.broadcast_to(mx[None], (B, C, C))], axis=2)         # (B,C,L+C)
    kk = jnp.concatenate([cache["k"].astype(q.dtype), k.astype(q.dtype)], axis=1)
    vv = jnp.concatenate([cache["v"].astype(q.dtype), v.astype(q.dtype)], axis=1)
    scale = scale if scale is not None else d ** -0.5
    return _mha_chunk(q, kk, vv, mask, scale)


def cache_write_decode(cache, k, v, pos, *, ring: bool):
    """Write one token at per-row position ``pos`` (B,) int32.

    Implemented as a select (where on a slot==iota mask), not a scatter:
    XLA:CPU expands bf16 scatters through an f32 promote/demote of the whole
    buffer (measured 13 GB/step on qwen2 decode_32k), and a masked select
    fuses cleanly on both backends.  The real-TPU serving path uses the
    paged-KV Pallas kernel (kernels/paged_attention) where the write is a
    single-page DMA."""
    L = cache["k"].shape[1]
    slot = (pos % L).astype(jnp.int32)
    hit = jnp.arange(L, dtype=jnp.int32)[None, :] == slot[:, None]   # (B,L)
    m = hit[:, :, None, None]
    ck = jnp.where(m, k[:, 0:1].astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(m, v[:, 0:1].astype(cache["v"].dtype), cache["v"])
    out = {"k": ck, "v": cv}
    if ring:
        out["pos"] = jnp.where(hit, pos[:, None], cache["pos"])
    return out


def cache_valid_mask(cache, pos, *, ring: bool, window: int):
    """(B, L) bool — slots visible to the token at per-row position pos."""
    B, L = cache["k"].shape[:2]
    if ring:
        sp = cache["pos"]
        m = (sp >= 0) & (sp <= pos[:, None])
        if window:
            m &= sp > (pos[:, None] - window)
        return m
    slots = jnp.arange(L)[None, :]
    return slots <= pos[:, None]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation == "gelu_plain":
        return {
            "w_in": ParamSpec((D, F), ("embed", "mlp")),
            "b_in": ParamSpec((F,), ("mlp",), init="zeros"),
            "w_out": ParamSpec((F, D), ("mlp", "embed")),
            "b_out": ParamSpec((D,), ("embed",), init="zeros"),
        }
    return {
        "w_gate": ParamSpec((D, F), ("embed", "mlp")),
        "w_up": ParamSpec((D, F), ("embed", "mlp")),
        "w_down": ParamSpec((F, D), ("mlp", "embed")),
    }


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def mlp_apply(p, x, cfg: ModelConfig, shd=_noop_shd):
    if cfg.mlp_activation == "gelu_plain":
        h = jnp.einsum("bsd,df->bsf", x, p["w_in"]) + p["b_in"].astype(x.dtype)
        h = _act("gelu", h)
        h = shd(h, ("batch", "act_seq", "mlp"))
        return jnp.einsum("bsf,fd->bsd", h, p["w_out"]) + p["b_out"].astype(x.dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = _act(cfg.mlp_activation, g) * u
    h = shd(h, ("batch", "act_seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts (per-row capacity dispatch, EP/TP shardable)
# ---------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "router": ParamSpec((D, E), ("embed", "experts_r")),
        "w_gate": ParamSpec((E, D, F), ("experts", "embed", "moe_mlp")),
        "w_up": ParamSpec((E, D, F), ("experts", "embed", "moe_mlp")),
        "w_down": ParamSpec((E, F, D), ("experts", "moe_mlp", "embed")),
    }


def _rank_within_expert(e_flat):
    """Per-row rank of each assignment within its expert (sort-based).

    e_flat: (B, T) int32 expert ids -> (B, T) int32 ranks.
    """
    B, T = e_flat.shape
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    first = jax.vmap(lambda es: jnp.searchsorted(es, es, side="left"))(e_sorted)
    ranks_sorted = jnp.arange(T, dtype=jnp.int32)[None, :] - first.astype(jnp.int32)
    inv = jnp.argsort(order, axis=1)
    return jnp.take_along_axis(ranks_sorted, inv, axis=1)


def moe_apply(p, x, cfg: ModelConfig, shd=_noop_shd):
    """x: (B,S,D) -> (y, aux_loss).  Per-row (sequence) capacity dispatch:
    no token movement across the batch/data axis, experts shard over model."""
    B, S, D = x.shape
    E, K, F = cfg.num_experts, cfg.experts_per_token, cfg.moe_d_ff
    logits = jnp.einsum("bsd,de->bse", x, p["router"], preferred_element_type=f32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, K)  # (B,S,K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # switch-style aux load-balancing loss
    me = probs.mean(axis=(0, 1))  # (E,)
    counts = jnp.zeros((E,), f32).at[idx.reshape(-1)].add(1.0)
    ce = counts / (B * S * K)
    aux = E * jnp.sum(me * ce)

    T = S * K
    C = max(1, int(math.ceil(S * K / E * cfg.capacity_factor)))
    e_flat = idx.reshape(B, T)
    tok = jnp.broadcast_to(jnp.repeat(jnp.arange(S, dtype=jnp.int32), K), (B, T))
    ranks = _rank_within_expert(e_flat)
    slot = jnp.where(ranks < C, e_flat * C + ranks, E * C)  # E*C = dropped
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]

    buf_tok = jnp.full((B, E * C), S, jnp.int32).at[rows, slot].set(tok, mode="drop")

    xp = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)  # sentinel row
    xs = jnp.take_along_axis(xp, buf_tok[:, :, None], axis=1)  # (B,E*C,D)
    xs = shd(xs.reshape(B, E, C, D), ("batch", "experts", None, None))

    g = jnp.einsum("becd,edf->becf", xs, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xs, p["w_up"])
    h = _act(cfg.mlp_activation, g) * u
    h = shd(h, ("batch", "experts", None, "moe_mlp"))
    yexp = jnp.einsum("becf,efd->becd", h, p["w_down"]).reshape(B, E * C, D)

    # combine by GATHER, not scatter-add: each token pulls its K slots back.
    # (a y.at[rows, buf_tok].add(...) combine forces GSPMD to replicate the
    # global-batch fp32 output — measured 8.6 GB/layer all-reduce + the
    # mirrored backward all-gather on qwen3-moe train_4k.)
    yp = jnp.concatenate([yexp, jnp.zeros((B, 1, D), yexp.dtype)], axis=1)
    gat = jnp.take_along_axis(yp, slot[:, :, None], axis=1)      # (B,T,D)
    y = (gat.reshape(B, S, K, D) * w[..., None].astype(gat.dtype)).sum(axis=2)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    d = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                                init="embed", scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return d


def embed_apply(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_logits(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embedding"], preferred_element_type=f32)
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"], preferred_element_type=f32)


def chunked_xent(p, x, labels, cfg: ModelConfig, shd=_noop_shd, *, chunk: int = 512,
                 mask=None):
    """Cross-entropy without materialising (B,S,V) logits: scan over seq chunks.
    x: (B,S,D) final hidden; labels: (B,S) int32. Returns (sum_nll, count)."""
    B, S, D = x.shape
    c = min(chunk, S)
    n = math.ceil(S / c)
    pad = n * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = x.reshape(B, n, c, D).swapaxes(0, 1)  # (n,B,c,D)
    ls = labels.reshape(B, n, c).swapaxes(0, 1)
    ms = None if mask is None else mask.reshape(B, n, c).swapaxes(0, 1)

    def body(carry, inp):
        if ms is None:
            xc, lc = inp
            valid = lc >= 0
        else:
            xc, lc, mc = inp
            valid = (lc >= 0) & mc
        logits = unembed_logits(p, xc, cfg)  # (B,c,V) f32
        logits = shd(logits, ("xent_batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit via iota-mask sum: shard-local on a vocab-sharded
        # logits buffer in fwd AND bwd.  (take_along_axis backward scatters
        # across the sharded vocab dim — XLA all-gathered the full fp32
        # logits, 8.6 GB/device/chunk on gemma3-27b.)
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        hit = vpos == jnp.maximum(lc, 0)[..., None]
        lbl = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        nll = jnp.where(valid, lse - lbl, 0.0)
        s, cnt = carry
        return (s + nll.sum(), cnt + valid.sum()), None

    # checkpoint: recompute chunk logits in backward instead of holding
    # n_chunks full (B,c,V) fp32 residuals (4.3 GiB/device on gemma3-27b)
    body = jax.checkpoint(body)
    inps = (xs, ls) if ms is None else (xs, ls, ms)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), f32), jnp.zeros((), jnp.int32)), inps)
    return tot, cnt
