"""Whisper-style encoder-decoder backbone (conv frontend stubbed per brief:
``input_specs()`` provides precomputed frame embeddings (B, encoder_seq, D)).

Encoder: bidirectional attention, learned positions.
Decoder: causal self-attention + cross-attention to encoder output; decode
caches hold self-KV plus the per-layer projected cross-KV (computed at
prefill, immutable afterwards).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import layers as L
from repro.models import params as P

f32 = jnp.float32


def _enc_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "mixer": L.attention_specs(cfg),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg),
    }


def _dec_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "self": L.attention_specs(cfg),
        "ln_x": L.layernorm_specs(cfg.d_model),
        "cross": L.attention_specs(cfg, cross=True),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.mlp_specs(cfg),
    }


class EncDec:
    def __init__(self, cfg: ModelConfig, perf: PerfConfig = BASELINE):
        self.cfg = cfg
        self.perf = perf

    # ------------------------------------------------------------- specs
    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg),
            "enc_pos": {"table": P.ParamSpec((cfg.encoder_seq, cfg.d_model),
                                             ("pos", "embed"), init="normal", scale=0.02)},
            "dec_pos": {"table": P.ParamSpec((cfg.max_position, cfg.d_model),
                                             ("pos", "embed"), init="normal", scale=0.02)},
            "encoder": P.stack(_enc_block_specs(cfg), cfg.num_encoder_layers),
            "enc_norm": L.layernorm_specs(cfg.d_model),
            "decoder": P.stack(_dec_block_specs(cfg), cfg.num_layers),
            "final_norm": L.layernorm_specs(cfg.d_model),
        }

    def cache_specs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        self_kv = L.kv_cache_specs(cfg, batch, max_len, ring=False)
        cross_kv = L.kv_cache_specs(cfg, batch, cfg.encoder_seq, ring=False)
        return {
            "self": P.stack(self_kv, cfg.num_layers),
            "cross": P.stack(cross_kv, cfg.num_layers),
        }

    # ------------------------------------------------------------- encoder
    def encode(self, params, frames, shd=L._noop_shd):
        """frames: (B, encoder_seq, D) precomputed embeddings (frontend stub)."""
        cfg, perf = self.cfg, self.perf
        x = frames.astype(jnp.bfloat16) + params["enc_pos"]["table"].astype(jnp.bfloat16)
        x = shd(x, ("batch", "act_seq", "embed"))

        def body(x, p):
            h = L.layernorm(p["ln1"], x, cfg.norm_eps)
            q, k, v = L._project_qkv(p["mixer"], h, cfg, None, 0.0, with_rope=False)
            ctx = L.attention_full(q, k, v, causal=False, q_chunk=perf.q_chunk)
            x = x + L.attn_out(p["mixer"], ctx)
            h = L.layernorm(p["ln2"], x, cfg.norm_eps)
            return x + L.mlp_apply(p["mlp"], h, cfg, shd), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.layernorm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------- decoder
    def _dec_embed(self, params, tokens, positions):
        x = L.embed_apply(params["embed"], tokens, self.cfg)
        pos_emb = jnp.take(params["dec_pos"]["table"], positions, axis=0)
        return x + pos_emb.astype(x.dtype)

    def _decoder(self, params, x, enc_out, *, mode, caches, pos, shd, max_len):
        cfg, perf = self.cfg, self.perf

        def body(carry, xs):
            x = carry
            p = xs[0]
            cache = xs[1] if mode == "decode" else None
            h = L.layernorm(p["ln1"], x, cfg.norm_eps)
            q, k, v = L._project_qkv(p["self"], h, cfg, None, 0.0, with_rope=False)
            new_self = None
            if mode == "decode":
                new_self = L.cache_write_decode(cache["self"], k, v, pos, ring=False)
                mask = L.cache_valid_mask(new_self, pos, ring=False, window=0)
                ctx = L.attention_decode(q, new_self["k"].astype(q.dtype),
                                         new_self["v"].astype(q.dtype), mask)
            else:
                ctx = L.attention_full(q, k, v, causal=True, q_chunk=perf.q_chunk,
                                       impl=perf.attn_impl)
                if mode == "prefill":
                    empty = jax.tree.map(
                        lambda s: jnp.zeros(s.shape, s.dtype),
                        P.abstract(L.kv_cache_specs(cfg, x.shape[0], max_len, ring=False)))
                    new_self = L.cache_write_prefill(empty, k, v, ring=False, window=0)
            x = x + L.attn_out(p["self"], ctx)

            # cross-attention
            h = L.layernorm(p["ln_x"], x, cfg.norm_eps)
            qx = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
            new_cross = None
            if mode == "decode":
                ck, cv = cache["cross"]["k"].astype(qx.dtype), cache["cross"]["v"].astype(qx.dtype)
                new_cross = cache["cross"]
                ctx = L.attention_full(qx, ck, cv, causal=False, q_chunk=perf.q_chunk)
            else:
                ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
                cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
                ctx = L.attention_full(qx, ck, cv, causal=False, q_chunk=perf.q_chunk)
                if mode == "prefill":
                    new_cross = {"k": ck, "v": cv}
            x = x + L.attn_out(p["cross"], ctx)

            h = L.layernorm(p["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(p["mlp"], h, cfg, shd)
            ys = None
            if mode != "train":
                ys = {"self": new_self, "cross": new_cross}
            return x, ys

        fn = body
        if mode == "train" and perf.remat != "none":
            fn = jax.checkpoint(body)
        xs = (params["decoder"],) if mode != "decode" else (params["decoder"],
                                                            {"self": caches["self"], "cross": caches["cross"]})

        def scan_body(c, s):  # adapt xs tuple
            return fn(c, s)

        x, ys = jax.lax.scan(scan_body, x, xs)
        new_caches = None
        if mode != "train":
            new_caches = {"self": ys["self"], "cross": ys["cross"]}
        return x, new_caches

    # ------------------------------------------------------------- public
    def loss(self, params, batch, shd=L._noop_shd):
        """batch: frames (B,Te,D) f32/bf16, tokens (B,S), labels (B,S)."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], shd)
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = self._dec_embed(params, batch["tokens"], positions[0])
        x = shd(x, ("batch", "act_seq", "embed"))
        x, _ = self._decoder(params, x, enc, mode="train", caches=None, pos=None,
                             shd=shd, max_len=0)
        x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
        nll, cnt = L.chunked_xent(params["embed"], x[:, :-1], batch["labels"][:, 1:],
                                  cfg, shd, chunk=self.perf.xent_chunk)
        loss = nll / jnp.maximum(cnt.astype(f32), 1.0)
        return loss, {"nll": nll, "tokens": cnt, "aux": jnp.zeros((), f32)}

    def prefill(self, params, batch, max_len: int, shd=L._noop_shd, true_len=None):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], shd)
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x = self._dec_embed(params, batch["tokens"], positions)
        x = shd(x, ("batch", "act_seq", "embed"))
        x, caches, = self._decoder(params, x, enc, mode="prefill", caches=None,
                                   pos=None, shd=shd, max_len=max_len)
        x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
        if true_len is None:
            x_last = x[:, -1:]
        else:
            li = jnp.maximum(true_len - 1, 0)[:, None, None]
            x_last = jnp.take_along_axis(x, li, axis=1)
        logits = L.unembed_logits(params["embed"], x_last, cfg)[:, 0]
        return logits, caches

    def decode_step(self, params, tokens, pos, caches, shd=L._noop_shd):
        cfg = self.cfg
        x = self._dec_embed(params, tokens, pos[:, None])
        x, caches = self._decoder(params, x, None, mode="decode", caches=caches,
                                  pos=pos, shd=shd, max_len=0)
        x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed_logits(params["embed"], x, cfg)[:, 0]
        return logits, caches
