"""Mamba-2 / SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD for train/prefill (intra-chunk quadratic term + inter-chunk
state recurrence via lax.scan), exact recurrent step for decode.  States are
fp32; matmuls bf16 with fp32 accumulation.  Heads shard over the model axis.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _noop_shd
from repro.models.params import ParamSpec

f32 = jnp.float32


def ssd_specs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, P, G, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "w_z": ParamSpec((D, H, P), ("embed", "heads", "qkv")),
        "w_x": ParamSpec((D, H, P), ("embed", "heads", "qkv")),
        "w_B": ParamSpec((D, G, N), ("embed", "groups", "state")),
        "w_C": ParamSpec((D, G, N), ("embed", "groups", "state")),
        "w_dt": ParamSpec((D, H), ("embed", "heads")),
        "conv_x": ParamSpec((H, P, K), ("heads", "qkv", "conv"), init="normal", scale=0.5),
        "conv_B": ParamSpec((G, N, K), ("groups", "state", "conv"), init="normal", scale=0.5),
        "conv_C": ParamSpec((G, N, K), ("groups", "state", "conv"), init="normal", scale=0.5),
        "A_log": ParamSpec((H,), ("heads",), dtype=f32, init="zeros"),
        "dt_bias": ParamSpec((H,), ("heads",), dtype=f32, init="zeros"),
        "D_skip": ParamSpec((H,), ("heads",), dtype=f32, init="ones"),
        "norm": {"scale": ParamSpec((H, P), ("heads", "qkv"), dtype=f32, init="zeros")},
        "w_out": ParamSpec((H, P, D), ("heads", "qkv", "embed")),
    }


def ssm_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    H, P, G, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "h": ParamSpec((batch, H, P, N), ("batch", "heads", "qkv", "state"), dtype=f32, init="zeros"),
        "conv_x": ParamSpec((batch, K - 1, H, P), ("batch", "conv", "heads", "qkv"), init="zeros"),
        "conv_B": ParamSpec((batch, K - 1, G, N), ("batch", "conv", "groups", "state"), init="zeros"),
        "conv_C": ParamSpec((batch, K - 1, G, N), ("batch", "conv", "groups", "state"), init="zeros"),
    }


def _causal_conv(x, w):
    """Depthwise causal conv along seq.  x: (B,S,...chan), w: (...chan,K)."""
    K = w.shape[-1]
    pad = [(0, 0)] * x.ndim
    pad[1] = (K - 1, 0)
    xp = jnp.pad(x, pad)
    out = sum(xp[:, j:j + x.shape[1]] * w[..., j] for j in range(K))
    return out


def _conv_step(state, xt, w):
    """state: (B,K-1,...), xt: (B,...) -> (y (B,...), new_state)."""
    K = w.shape[-1]
    full = jnp.concatenate([state, xt[:, None]], axis=1)  # (B,K,...)
    y = sum(full[:, j] * w[..., j] for j in range(K))
    return y, full[:, 1:]


def _gated_norm(p_norm, y, z, eps):
    y = y * jax.nn.silu(z.astype(f32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)  # over P, per head
    y = y * jax.lax.rsqrt(var + eps)
    return y * (p_norm["scale"] + 1.0)


def _project(p, x, cfg):
    z = jnp.einsum("bsd,dhp->bshp", x, p["w_z"])
    xr = jnp.einsum("bsd,dhp->bshp", x, p["w_x"])
    Br = jnp.einsum("bsd,dgn->bsgn", x, p["w_B"])
    Cr = jnp.einsum("bsd,dgn->bsgn", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(f32)
    return z, xr, Br, Cr, dt


def _expand_heads(t, H):
    """(B,...,G,N) -> (B,...,H,N) repeating each group H//G times."""
    G = t.shape[-2]
    rep = H // G
    return jnp.repeat(t, rep, axis=-2) if rep > 1 else t


def _ssd_scan_chunks(xc, Bc, Cc, da, dt, h0, H: int, Q: int):
    """Chunked SSD scan over conv-activated projections.

    xc: (B,S,H,P), Bc/Cc: (B,S,G,N), da/dt: (B,S,H), h0: (B,H,P,N) initial
    state (zeros for a fresh sequence).  S must be a multiple of Q.
    Returns (h_last, y (B,S,H,P) f32).
    """
    B, S = xc.shape[:2]
    nc = S // Q

    def chunkify(t):  # (B,S,...) -> (nc,B,Q,...)
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xq, Bq, Cq, daq, dtq = map(chunkify, (xc, Bc, Cc, da, dt))

    def body(h, inp):
        xk, Bk, Ck, dak, dtk = inp  # (B,Q,H,P) (B,Q,G,N) (B,Q,G,N) (B,Q,H) (B,Q,H)
        cum = jnp.cumsum(dak, axis=1)  # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,q,t,H) = cum_q - cum_t
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)  # (B,q,t,H)
        CB = jnp.einsum("bqgn,btgn->bqtg", Ck, Bk, preferred_element_type=f32)
        M = _expand_heads(CB, H) * L
        xdt = (xk.astype(f32) * dtk[..., None])
        y_in = jnp.einsum("bqth,bthp->bqhp", M.astype(xk.dtype), xdt.astype(xk.dtype),
                          preferred_element_type=f32)
        Ch = _expand_heads(Ck, H)  # (B,Q,H,N)
        y_off = jnp.einsum("bqhn,bhpn->bqhp", Ch.astype(xk.dtype), h.astype(xk.dtype),
                           preferred_element_type=f32)
        y_off = y_off * jnp.exp(cum)[..., None]  # decay from chunk start to q
        # state update
        wt = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        Bh = _expand_heads(Bk, H)           # (B,Q,H,N)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bthn,bthp->bhpn", (Bh.astype(f32) * wt[..., None]).astype(xk.dtype),
            xdt.astype(xk.dtype), preferred_element_type=f32)
        return h_new, (y_in + y_off)

    h_last, ys = jax.lax.scan(body, h0, (xq, Bq, Cq, daq, dtq))
    return h_last, ys.swapaxes(0, 1).reshape(B, S, *ys.shape[3:])


def ssd_apply_full(p, x, cfg: ModelConfig, shd=_noop_shd, *, want_state: bool = False,
                   true_len=None, use_pallas: bool = False, interpret: bool = True):
    """Full-sequence SSD.  x: (B,S,D) -> (y, cache|None).

    Non-divisible S is front-padded with zeros to a chunk multiple: leading
    zero tokens are exact no-ops for the causal conv (matches zero left-pad)
    and contribute nothing to the state (x=0 after silu(conv(0))=0), so both
    the sliced outputs and the final state are unchanged.

    ``true_len`` (B,) int32 supports right-padded prompts: pad positions get
    dt=0 and x=0, making them exact no-ops for the state recurrence; the conv
    tail cache is gathered at per-row valid positions.
    """
    B, S_in, D = x.shape
    Q = min(cfg.ssm_chunk, S_in)
    lead = (-S_in) % Q
    if lead:
        x = jnp.pad(x, ((0, 0), (lead, 0), (0, 0)))
    B, S, D = x.shape
    H, P, G, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state

    z, xr, Br, Cr, dt = _project(p, x, cfg)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_x"]))
    Bc = jax.nn.silu(_causal_conv(Br, p["conv_B"]))
    Cc = jax.nn.silu(_causal_conv(Cr, p["conv_C"]))
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,S,H) f32
    if true_len is not None:
        seq_idx = jnp.arange(S, dtype=jnp.int32)[None, :] - lead  # (1,S)
        valid = seq_idx < true_len[:, None]                       # (B,S)
        dt = jnp.where(valid[..., None], dt, 0.0)
        xc = jnp.where(valid[..., None, None], xc, 0.0)
    a = -jnp.exp(p["A_log"].astype(f32))     # (H,)
    da = dt * a                              # (B,S,H) <= 0

    if use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_chunked_scan
        Bh = _expand_heads(Bc, H)
        Ch = _expand_heads(Cc, H)
        y, h_last = ssd_chunked_scan(xc, Bh, Ch, dt, da, chunk=Q,
                                     use_pallas=True, interpret=interpret)
    else:
        h0 = jnp.zeros((B, H, P, N), f32)
        h_last, y = _ssd_scan_chunks(xc, Bc, Cc, da, dt, h0, H, Q)
    y = y + p["D_skip"][:, None] * xc.astype(f32)
    y = _gated_norm(p["norm"], y, z, cfg.norm_eps)
    y = shd(y.astype(x.dtype), ("batch", "act_seq", "heads", "qkv"))
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"])
    if lead:
        out = out[:, lead:]
    if not want_state:
        return out, None
    K = cfg.ssm_conv
    assert S >= K - 1, "prefill shorter than conv receptive field"
    if true_len is None:
        def tail(t):
            return t[:, S - (K - 1):]
    else:
        # per-row last K-1 *valid* raw projections (pre-conv) for the decode
        # conv state; rows assumed to have true_len >= K-1
        idx = lead + true_len[:, None] - (K - 1) + jnp.arange(K - 1, dtype=jnp.int32)[None, :]
        idx = jnp.maximum(idx, 0)

        def tail(t):
            ix = idx.reshape(B, K - 1, *([1] * (t.ndim - 2)))
            return jnp.take_along_axis(t, ix, axis=1)
    cache = {
        "h": h_last,
        "conv_x": tail(xr).astype(x.dtype),
        "conv_B": tail(Br).astype(x.dtype),
        "conv_C": tail(Cr).astype(x.dtype),
    }
    return out, cache


def ssd_apply_chunk(p, x, cache, cfg: ModelConfig, shd=_noop_shd, *, true_len):
    """One chunked-prefill step with carried state.

    x: (B,C,D) right-padded chunk of a longer prompt; ``cache`` holds the SSM
    state after the previous chunks (zeros for the first chunk); ``true_len``
    (B,) int32 counts the valid tokens of this chunk (0 == row is a no-op:
    its returned cache row equals the input row).  Matches ssd_apply_full on
    the concatenated sequence: pad positions get dt=0 / x=0 (exact state
    no-ops) and the causal conv reads the cached last K-1 raw projections
    instead of zero left-padding.  Returns (y (B,C,D), new cache).
    """
    B, C, D = x.shape
    H, P, G, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    K = cfg.ssm_conv
    z, xr, Br, Cr, dt = _project(p, x, cfg)
    # conv over [cached raw tail (K-1) | chunk]; outputs at concat positions
    # >= K-1 see the true history, so slicing [K-1:] is exact for the chunk
    xcat = jnp.concatenate([cache["conv_x"].astype(xr.dtype), xr], axis=1)
    Bcat = jnp.concatenate([cache["conv_B"].astype(Br.dtype), Br], axis=1)
    Ccat = jnp.concatenate([cache["conv_C"].astype(Cr.dtype), Cr], axis=1)
    xc = jax.nn.silu(_causal_conv(xcat, p["conv_x"])[:, K - 1:])
    Bc = jax.nn.silu(_causal_conv(Bcat, p["conv_B"])[:, K - 1:])
    Cc = jax.nn.silu(_causal_conv(Ccat, p["conv_C"])[:, K - 1:])
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,C,H) f32
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < true_len[:, None]
    dt = jnp.where(valid[..., None], dt, 0.0)
    xc = jnp.where(valid[..., None, None], xc, 0.0)
    a = -jnp.exp(p["A_log"].astype(f32))
    da = dt * a

    Q = min(cfg.ssm_chunk, C)
    lead = (-C) % Q
    if lead:  # zero front-pad to a chunk multiple: dt=0/x=0 state no-ops
        def pad(t):
            return jnp.pad(t, ((0, 0), (lead, 0)) + ((0, 0),) * (t.ndim - 2))
        xc_p, Bc_p, Cc_p, da_p, dt_p, z_p = map(pad, (xc, Bc, Cc, da, dt, z))
    else:
        xc_p, Bc_p, Cc_p, da_p, dt_p, z_p = xc, Bc, Cc, da, dt, z
    h_last, y = _ssd_scan_chunks(xc_p, Bc_p, Cc_p, da_p, dt_p,
                                 cache["h"].astype(f32), H, Q)
    y = y + p["D_skip"][:, None] * xc_p.astype(f32)
    y = _gated_norm(p["norm"], y, z_p, cfg.norm_eps)
    y = shd(y.astype(x.dtype), ("batch", "act_seq", "heads", "qkv"))
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"])
    if lead:
        out = out[:, lead:]

    # new conv tail: last K-1 raw projections ending at the last valid token.
    # xcat index of the last valid token is (K-1) + true_len - 1, so the tail
    # is xcat[true_len : true_len + K-1]; true_len == 0 reproduces the old
    # cached tail exactly (the no-op row guarantee).
    idx = true_len[:, None] + jnp.arange(K - 1, dtype=jnp.int32)[None, :]

    def tail(t):
        ix = idx.reshape(B, K - 1, *([1] * (t.ndim - 2)))
        return jnp.take_along_axis(t, ix, axis=1)

    new_cache = {
        "h": h_last,
        "conv_x": tail(xcat).astype(cache["conv_x"].dtype),
        "conv_B": tail(Bcat).astype(cache["conv_B"].dtype),
        "conv_C": tail(Ccat).astype(cache["conv_C"].dtype),
    }
    return out, new_cache


def ssd_apply_decode(p, x, cache, cfg: ModelConfig, shd=_noop_shd):
    """One-token recurrent step.  x: (B,1,D) -> (y (B,1,D), new cache)."""
    H, P, G, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    z, xr, Br, Cr, dt = _project(p, x, cfg)
    xt, nconv_x = _conv_step(cache["conv_x"], xr[:, 0], p["conv_x"])
    Bt, nconv_B = _conv_step(cache["conv_B"], Br[:, 0], p["conv_B"])
    Ct, nconv_C = _conv_step(cache["conv_C"], Cr[:, 0], p["conv_C"])
    xt, Bt, Ct = jax.nn.silu(xt), jax.nn.silu(Bt), jax.nn.silu(Ct)
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"].astype(f32))
    da = jnp.exp(dt * a)  # (B,H)
    Bh = _expand_heads(Bt, H).astype(f32)  # (B,H,N)
    Ch = _expand_heads(Ct, H).astype(f32)
    xdt = xt.astype(f32) * dt[..., None]   # (B,H,P)
    h = cache["h"] * da[:, :, None, None] + jnp.einsum("bhn,bhp->bhpn", Bh, xdt)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + p["D_skip"][:, None] * xt.astype(f32)
    y = _gated_norm(p["norm"], y, z[:, 0], cfg.norm_eps)
    out = jnp.einsum("bhp,hpd->bd", y.astype(x.dtype), p["w_out"])[:, None]
    new_cache = {"h": h, "conv_x": nconv_x.astype(x.dtype), "conv_B": nconv_B.astype(x.dtype),
                 "conv_C": nconv_C.astype(x.dtype)}
    return out, new_cache
