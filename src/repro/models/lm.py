"""Unified decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Layer execution is ``lax.scan`` over *groups*: the repeating pattern unit of
the architecture (1 layer for uniform stacks, 6 for gemma3's 5 local + 1
global, 8 for a Jamba block).  Group params are stacked along a leading axis,
so HLO size is O(pattern), not O(layers).  Non-divisible remainder layers are
unrolled as a tail.

Modes:
  train   — full-seq, no cache, remat-able
  prefill — full-seq, emits per-layer cache (KV / ring-KV / SSM state)
  decode  — one token per row at per-row positions, consumes+produces cache
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import params as P

f32 = jnp.float32


def _group_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_every:
        p = math.lcm(p, cfg.attn_every)
    if cfg.local_ratio:
        p = math.lcm(p, cfg.local_ratio + 1)
    if cfg.num_experts:
        p = math.lcm(p, cfg.moe_every)
    return p


def block_specs(cfg: ModelConfig, kind: str, is_moe: bool) -> dict:
    d: dict[str, Any] = {"ln1": L.rmsnorm_specs(cfg.d_model)}
    d["mixer"] = M.ssd_specs(cfg) if kind == "ssm" else L.attention_specs(cfg)
    d["ln2"] = L.rmsnorm_specs(cfg.d_model)
    d["mlp"] = L.moe_specs(cfg) if is_moe else L.mlp_specs(cfg)
    return d


class LM:
    def __init__(self, cfg: ModelConfig, perf: PerfConfig = BASELINE):
        self.cfg = cfg
        self.perf = perf
        p = _group_period(cfg)
        self.period = p
        self.groups = cfg.num_layers // p
        self.tail_layers = list(range(self.groups * p, cfg.num_layers))
        self.kinds = [cfg.layer_kind(j) for j in range(p)]
        self.moes = [cfg.layer_is_moe(j) for j in range(p)]
        for i in range(cfg.num_layers):
            if i < self.groups * p:
                assert cfg.layer_kind(i) == self.kinds[i % p], (i, cfg.name)
                assert cfg.layer_is_moe(i) == self.moes[i % p], (i, cfg.name)

    # ------------------------------------------------------------- specs
    def param_specs(self) -> dict:
        cfg = self.cfg
        group = {f"m{j}": block_specs(cfg, self.kinds[j], self.moes[j])
                 for j in range(self.period)}
        specs = {
            "embed": L.embed_specs(cfg),
            "final_norm": L.rmsnorm_specs(cfg.d_model),
            "blocks": P.stack(group, self.groups),
        }
        if self.tail_layers:
            specs["tail"] = {
                f"t{i}": block_specs(cfg, cfg.layer_kind(i), cfg.layer_is_moe(i))
                for i in self.tail_layers
            }
        return specs

    def _entry_specs(self, kind: str, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        if kind == "ssm":
            return M.ssm_cache_specs(cfg, batch)
        w = cfg.window_for(kind)
        ring = w > 0
        length = min(w, max_len) if ring else max_len
        return L.kv_cache_specs(cfg, batch, length, ring=ring)

    def cache_specs(self, batch: int, max_len: int) -> dict:
        group = {f"m{j}": self._entry_specs(self.kinds[j], batch, max_len)
                 for j in range(self.period)}
        specs = {"blocks": P.stack(group, self.groups)}
        if self.tail_layers:
            specs["tail"] = {
                f"t{i}": self._entry_specs(self.cfg.layer_kind(i), batch, max_len)
                for i in self.tail_layers
            }
        return specs

    # ------------------------------------------------------------- blocks
    def _block(self, p, x, kind, is_moe, *, mode, positions, cache, pos,
               prefix_len, max_len, shd, true_len=None, block_table=None,
               live=None):
        cfg, perf = self.cfg, self.perf
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        new_cache = None
        if kind == "ssm":
            if mode == "decode":
                mix, new_cache = M.ssd_apply_decode(p["mixer"], h, cache, cfg, shd)
            elif mode == "chunk":
                mix, new_cache = M.ssd_apply_chunk(p["mixer"], h, cache, cfg, shd,
                                                   true_len=true_len)
            else:
                mix, new_cache = M.ssd_apply_full(
                    p["mixer"], h, cfg, shd, want_state=(mode == "prefill"),
                    true_len=true_len if mode == "prefill" else None,
                    use_pallas=perf.use_pallas, interpret=perf.pallas_interpret)
        else:
            window = cfg.window_for(kind)
            theta = cfg.rope_theta_local if kind == "attn_local" else cfg.rope_theta
            q, k, v = L._project_qkv(p["mixer"], h, cfg, positions, theta)
            q = shd(q, ("batch", "act_seq", "heads", "qkv"))
            if mode == "decode":
                new_cache = L.cache_write_decode(cache, k, v, pos, ring=window > 0)
                mask = L.cache_valid_mask(new_cache, pos, ring=window > 0, window=window)
                ctx = L.attention_decode(q, new_cache["k"].astype(q.dtype),
                                         new_cache["v"].astype(q.dtype), mask)
            elif mode == "paged_decode":
                # cache = per-layer paged pools; one write DMA + decode
                # attention driven by the shared per-row block table.  The
                # Pallas kernel is the accelerator path; the jnp path gathers
                # the pools into the dense slot layout and reuses
                # attention_decode bit-for-bit, so a paged engine is
                # token-identical to a dense one on CPU.
                from repro.kernels.paged_attention.ops import paged_decode_attention
                from repro.serving.kv_cache import paged_gather, paged_write
                kp, vp = paged_write(cache["k"], cache["v"], block_table, pos,
                                     k[:, 0], v[:, 0], live=live)
                if perf.use_pallas:
                    ctx_len = pos + 1
                    if live is not None:
                        ctx_len = jnp.where(live, ctx_len, 0)
                    ctx = paged_decode_attention(
                        q[:, 0], kp, vp, block_table, ctx_len,
                        use_pallas=True,
                        interpret=perf.pallas_interpret)[:, None]
                else:
                    S_ctx = block_table.shape[1] * cache["k"].shape[1]
                    gk = paged_gather(kp, block_table, S_ctx)
                    gv = paged_gather(vp, block_table, S_ctx)
                    mask = (jnp.arange(S_ctx, dtype=jnp.int32)[None, :]
                            <= pos[:, None])
                    if live is not None:
                        mask = jnp.logical_and(mask, live[:, None])
                    ctx = L.attention_decode(q, gk.astype(q.dtype),
                                             gv.astype(q.dtype), mask)
                new_cache = {"k": kp, "v": vp}
            elif mode == "paged_chunk":
                # attend previously-written blocks (positions < pos0) through
                # a gathered contiguous view, then append this chunk's k/v
                # into allocator-extended blocks
                from repro.serving.kv_cache import paged_gather, paged_write_chunk
                S_ctx = block_table.shape[1] * cache["k"].shape[1]
                gk = paged_gather(cache["k"], block_table, S_ctx).astype(q.dtype)
                gv = paged_gather(cache["v"], block_table, S_ctx).astype(q.dtype)
                ctx = L.attention_chunk(q, k, v, {"k": gk, "v": gv}, pos,
                                        window=0, ring=False)
                kp, vp = paged_write_chunk(cache["k"], cache["v"], block_table,
                                           pos, true_len, k, v)
                new_cache = {"k": kp, "v": vp}
            elif mode == "chunk":
                # attend the pre-write cache + this chunk's own k/v, then
                # append the chunk (pos = chunk start, true_len = valid count)
                ctx = L.attention_chunk(q, k, v, cache, pos,
                                        window=window, ring=window > 0)
                new_cache = L.cache_write_chunk(cache, k, v, pos, true_len,
                                                ring=window > 0)
            else:
                if perf.use_pallas and prefix_len == 0:
                    from repro.kernels.flash_attention.ops import attention as FA
                    ctx = FA(q, k, v, causal=True, window=window,
                             use_pallas=True, bq=min(128, q.shape[1]),
                             bk=min(128, k.shape[1]),
                             interpret=perf.pallas_interpret)
                else:
                    ctx = L.attention_full(
                        q, k, v, causal=True, window=window, prefix_len=prefix_len,
                        q_chunk=perf.q_chunk, impl=perf.attn_impl)
                if mode == "prefill":
                    empty = self._empty_cache_entry(kind, x.shape[0], max_len, x.dtype)
                    new_cache = L.cache_write_prefill(empty, k, v, ring=window > 0,
                                                      window=window, true_len=true_len)
            mix = L.attn_out(p["mixer"], ctx)
        x = x + mix
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if is_moe:
            y, aux = L.moe_apply(p["mlp"], h2, cfg, shd)
        else:
            y, aux = L.mlp_apply(p["mlp"], h2, cfg, shd), jnp.zeros((), f32)
        return x + y, new_cache, aux

    def _empty_cache_entry(self, kind: str, batch: int, max_len: int, dtype):
        specs = self._entry_specs(kind, batch, max_len)
        kv_dtype = jnp.dtype(self.perf.kv_dtype) if kind != "ssm" else None

        def mk(s: P.ParamSpec):
            dt = s.dtype
            if kv_dtype is not None and s.dtype == jnp.bfloat16:
                dt = kv_dtype
            if s.init == "const":
                return jnp.full(s.shape, s.scale, dt)
            return jnp.zeros(s.shape, dt)

        return P.tree_map_specs(mk, specs)

    # ------------------------------------------------------------- trunk
    def _trunk(self, params, x, *, mode, positions, caches, pos, prefix_len,
               max_len, shd, true_len=None, block_table=None, live=None):
        """Run all layers; returns (x, new_caches, aux_total)."""
        cfg, perf = self.cfg, self.perf
        cached_modes = ("decode", "chunk", "paged_decode", "paged_chunk")

        def group_body(carry, xs):
            x, aux = carry
            gparams = xs[0]
            gcache = xs[1] if mode in cached_modes else None
            new_entries = {}
            for j in range(self.period):
                c = gcache[f"m{j}"] if gcache is not None else None
                x, nc, a = self._block(
                    gparams[f"m{j}"], x, self.kinds[j], self.moes[j],
                    mode=mode, positions=positions, cache=c, pos=pos,
                    prefix_len=prefix_len, max_len=max_len, shd=shd,
                    true_len=true_len, block_table=block_table, live=live)
                aux = aux + a
                if nc is not None:
                    new_entries[f"m{j}"] = nc
            ys = new_entries if (mode != "train") else None
            return (x, aux), ys

        body = group_body
        if mode == "train" and perf.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if perf.remat == "dots" else None)
            body = jax.checkpoint(group_body, policy=policy)

        if mode == "decode" and perf.decode_unroll:
            aux = jnp.zeros((), f32)
            new_groups = []
            for g in range(self.groups):
                gparams = jax.tree.map(lambda a: a[g], params["blocks"])
                gcache = jax.tree.map(lambda a: a[g], caches["blocks"])
                new_entries = {}
                for j in range(self.period):
                    x, nc, a = self._block(
                        gparams[f"m{j}"], x, self.kinds[j], self.moes[j],
                        mode=mode, positions=positions, cache=gcache[f"m{j}"],
                        pos=pos, prefix_len=prefix_len, max_len=max_len,
                        shd=shd, true_len=true_len)
                    aux = aux + a
                    new_entries[f"m{j}"] = nc
                new_groups.append(new_entries)
            group_caches = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_groups)
        else:
            xs = (params["blocks"],)
            if mode in cached_modes:
                xs = (params["blocks"], caches["blocks"])
            (x, aux), group_caches = jax.lax.scan(body, (x, jnp.zeros((), f32)), xs)

        tail_caches = {}
        for i in self.tail_layers:
            tp = params["tail"][f"t{i}"]
            c = caches["tail"][f"t{i}"] if mode in cached_modes else None
            x, nc, a = self._block(
                tp, x, cfg.layer_kind(i), cfg.layer_is_moe(i),
                mode=mode, positions=positions, cache=c, pos=pos,
                prefix_len=prefix_len, max_len=max_len, shd=shd,
                true_len=true_len, block_table=block_table, live=live)
            aux = aux + a
            if nc is not None:
                tail_caches[f"t{i}"] = nc

        new_caches = None
        if mode != "train":
            new_caches = {"blocks": group_caches}
            if self.tail_layers:
                new_caches["tail"] = tail_caches
        return x, new_caches, aux

    # ------------------------------------------------------------- inputs
    def _embed_inputs(self, params, batch, shd):
        """tokens (+ optional vlm patches) -> (x, positions, prefix_len)."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], batch["tokens"], cfg)
        prefix = 0
        if cfg.num_vision_tokens:
            patches = batch["patches"].astype(x.dtype)
            if cfg.scale_embed:
                patches = patches * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix = cfg.num_vision_tokens
        x = shd(x, ("batch", "act_seq", "embed"))
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        return x, positions, prefix

    # ------------------------------------------------------------- public
    def loss(self, params, batch, shd=L._noop_shd):
        """batch: tokens (B,S[,text]) int32, labels (B,S_text) int32 (-1 pad)."""
        cfg = self.cfg
        x, positions, prefix = self._embed_inputs(params, batch, shd)
        x, _, aux = self._trunk(params, x, mode="train", positions=positions,
                                caches=None, pos=None, prefix_len=prefix,
                                max_len=0, shd=shd)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if prefix:
            x = x[:, prefix - 1:-1]  # hidden states predicting each text token
            labels = batch["labels"]
        else:
            x = x[:, :-1]
            labels = batch["labels"][:, 1:]
        nll, cnt = L.chunked_xent(params["embed"], x, labels, cfg, shd,
                                  chunk=self.perf.xent_chunk)
        loss = nll / jnp.maximum(cnt.astype(f32), 1.0)
        if cfg.num_experts:
            loss = loss + cfg.aux_loss_weight * aux / max(cfg.num_layers, 1)
        return loss, {"nll": nll, "tokens": cnt, "aux": aux}

    def prefill(self, params, batch, max_len: int, shd=L._noop_shd, true_len=None):
        """Full-sequence prefill.  Returns (last-token logits (B,V) f32, cache).

        ``true_len`` (B,) int32: number of valid *text* tokens per row for
        right-padded (bucketed) prompts; logits come from the last valid
        position and ring/SSM caches exclude pad positions.  The absolute
        sequence length includes any vision prefix."""
        cfg = self.cfg
        x, positions, prefix = self._embed_inputs(params, batch, shd)
        abs_len = None if true_len is None else true_len + prefix
        x, caches, _ = self._trunk(params, x, mode="prefill", positions=positions,
                                   caches=None, pos=None, prefix_len=prefix,
                                   max_len=max_len, shd=shd, true_len=abs_len)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if true_len is None:
            x_last = x[:, -1:]
        else:
            li = (abs_len - 1)[:, None, None]
            x_last = jnp.take_along_axis(x, jnp.maximum(li, 0), axis=1)
        logits = L.unembed_logits(params["embed"], x_last, cfg)[:, 0]
        return logits, caches

    def prefill_chunk(self, params, tokens, pos0, n_valid, caches, shd=L._noop_shd):
        """One bucket-sized chunk of a long prompt, batched over cache rows.

        tokens (B,C) int32 right-padded chunk; pos0 (B,) int32 absolute start
        positions; n_valid (B,) int32 valid tokens per row — 0 marks an idle
        row whose cache is returned bit-identical (the engine runs its whole
        decode pool through one program regardless of how many rows are mid-
        prefill).  ``caches`` is the full pool cache tree; the chunk's K/V
        (or SSM state) is appended in place of raising on long prompts.

        Returns (logits (B,V) f32 at each row's last valid chunk position,
        new caches).  Text-only decoders — no vision prefix or encoder; the
        engine gates admission accordingly.

        Exactness: attention / ring / SSM chunked prefill matches full-seq
        prefill (fp rounding aside).  MoE capacity dispatch is per-call, so
        its token-drop pattern under router skew may differ from a single
        full-sequence prefill — inherent to capacity-based MoE (decode, with
        one slot per expert per token, never drops either way).
        """
        cfg = self.cfg
        x = L.embed_apply(params["embed"], tokens, cfg)
        x = shd(x, ("batch", "act_seq", "embed"))
        C = tokens.shape[1]
        positions = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        x, caches, _ = self._trunk(params, x, mode="chunk", positions=positions,
                                   caches=caches, pos=pos0, prefix_len=0,
                                   max_len=0, shd=shd, true_len=n_valid)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        li = jnp.maximum(n_valid - 1, 0)[:, None, None]
        x_last = jnp.take_along_axis(x, li, axis=1)
        logits = L.unembed_logits(params["embed"], x_last, cfg)[:, 0]
        return logits, caches

    # ------------------------------------------------------------- paged
    def supports_paged(self) -> bool:
        """Paged KV serving covers pure decoders whose every layer is global
        attention: SSM/conv state is per-row (nothing to page), ring layers
        keep their own slot-position bookkeeping, and vision/encoder
        prefixes pin absolute layout.  The engine falls back to the dense
        RowPool backend for those families."""
        cfg = self.cfg
        kinds = set(self.kinds) | {cfg.layer_kind(i) for i in self.tail_layers}
        return (not cfg.is_encoder_decoder and not cfg.num_vision_tokens
                and kinds == {"attn"} and cfg.window_for("attn") == 0)

    def paged_cache_specs(self, num_blocks: int, block_size: int) -> dict:
        """Per-layer paged pool specs, mirroring :meth:`cache_specs`'s tree
        structure so the same scan-over-groups trunk consumes them.  Every
        layer indexes its pool through one shared per-row block table."""
        assert self.supports_paged(), f"{self.cfg.name}: not paged-servable"
        cfg = self.cfg
        kv_dtype = jnp.dtype(self.perf.kv_dtype)

        def entry():
            shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
            axes = ("kv_blocks", "kv_slot", "kv_heads", "qkv")
            return {"k": P.ParamSpec(shape, axes, dtype=kv_dtype, init="zeros"),
                    "v": P.ParamSpec(shape, axes, dtype=kv_dtype, init="zeros")}

        specs = {"blocks": P.stack({f"m{j}": entry()
                                    for j in range(self.period)}, self.groups)}
        if self.tail_layers:
            specs["tail"] = {f"t{i}": entry() for i in self.tail_layers}
        return specs

    def decode_step_paged(self, params, tokens, pos, pools, block_table,
                          live=None, shd=L._noop_shd):
        """Decode step against paged KV pools.

        tokens (B,1) int32; pos (B,) int32 absolute positions; pools: the
        paged cache tree (:meth:`paged_cache_specs`); block_table
        (B, max_blk) int32, -1 = unmapped; live (B,) bool — False rows
        (empty or mid-prefill) neither write their token nor count context.
        Returns (logits (B,V) f32, new pools)."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], tokens, cfg)
        positions = pos[:, None]
        x, pools, _ = self._trunk(params, x, mode="paged_decode",
                                  positions=positions, caches=pools, pos=pos,
                                  prefix_len=0, max_len=0, shd=shd,
                                  block_table=block_table, live=live)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed_logits(params["embed"], x, cfg)[:, 0]
        return logits, pools

    def prefill_chunk_paged(self, params, tokens, pos0, n_valid, pools,
                            block_table, shd=L._noop_shd):
        """Chunked prefill appending into paged pools (the paged counterpart
        of :meth:`prefill_chunk`).  A prefix-cache hit simply starts the
        first chunk at pos0 = n_cached: the shared blocks already hold those
        positions' KV, so the skipped tokens are never embedded or attended.
        Rows with n_valid == 0 are exact no-ops."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], tokens, cfg)
        x = shd(x, ("batch", "act_seq", "embed"))
        C = tokens.shape[1]
        positions = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        x, pools, _ = self._trunk(params, x, mode="paged_chunk",
                                  positions=positions, caches=pools, pos=pos0,
                                  prefix_len=0, max_len=0, shd=shd,
                                  true_len=n_valid, block_table=block_table)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        li = jnp.maximum(n_valid - 1, 0)[:, None, None]
        x_last = jnp.take_along_axis(x, li, axis=1)
        logits = L.unembed_logits(params["embed"], x_last, cfg)[:, 0]
        return logits, pools

    def decode_step(self, params, tokens, pos, caches, shd=L._noop_shd):
        """tokens (B,1) int32, pos (B,) int32 absolute positions in the full
        (prefix + text) sequence.  Returns (logits (B,V) f32, new caches)."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], tokens, cfg)
        positions = pos[:, None]
        x, caches, _ = self._trunk(params, x, mode="decode", positions=positions,
                                   caches=caches, pos=pos, prefix_len=0,
                                   max_len=0, shd=shd)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed_logits(params["embed"], x, cfg)[:, 0]
        return logits, caches


def make_model(cfg: ModelConfig, perf: PerfConfig = BASELINE):
    if cfg.is_encoder_decoder:
        from repro.models.whisper import EncDec
        return EncDec(cfg, perf)
    return LM(cfg, perf)
