from repro.serving.api import (SSE_DONE, CompletionChunk,  # noqa: F401
                               CompletionError, CompletionRequest,
                               CompletionResponse, CompletionsAPI,
                               ModelInfo, ModelList, ModelsAPI, StreamDemux)
from repro.serving.engine import InferenceEngine, StepStats  # noqa: F401
from repro.serving.events import (EngineEvent, FinishEvent,  # noqa: F401
                                  FirstTokenEvent, PreemptEvent, TokenEvent)
from repro.serving.prefix_cache import PrefixCache  # noqa: F401
from repro.serving.request import Request, SamplingParams, State  # noqa: F401
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
