from repro.serving.engine import InferenceEngine, StepStats  # noqa: F401
from repro.serving.prefix_cache import PrefixCache  # noqa: F401
from repro.serving.request import Request, SamplingParams, State  # noqa: F401
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
