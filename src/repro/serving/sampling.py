"""Batched token sampling: greedy / temperature / top-k / top-p.

One jit-able function over (B, V) logits with per-row parameter vectors, so
the engine never recompiles when request mixes change.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32
NEG = -1e30


def sample(logits, key, temperature, top_k, top_p):
    """logits (B,V) f32; temperature/top_p (B,) f32; top_k (B,) int32.

    temperature == 0 selects greedy for that row.  top_k == 0 disables top-k.
    Returns (B,) int32 tokens.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = logits / t

    # top-k: mask everything below the k-th largest
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.clip(jnp.where(top_k <= 0, V, top_k), 1, V)
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=1)
    scaled = jnp.where(scaled >= kth, scaled, NEG)

    # top-p (nucleus): keep the smallest prefix of sorted probs with mass >= p
    probs_sorted = jax.nn.softmax(jnp.sort(scaled, axis=-1)[:, ::-1], axis=-1)
    cum = jnp.cumsum(probs_sorted, axis=-1)
    # number of tokens kept per row (always >= 1)
    keep = jnp.sum(cum - probs_sorted < top_p[:, None], axis=-1)
    keep = jnp.clip(keep, 1, V)
    cutoff = jnp.take_along_axis(jnp.sort(scaled, axis=-1)[:, ::-1],
                                 (keep - 1)[:, None], axis=1)
    scaled = jnp.where(scaled >= cutoff, scaled, NEG)

    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def make_sampler():
    return jax.jit(sample)
