"""Request lifecycle objects shared by the engine and the control plane."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any


class State(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    MIGRATING = "migrating"
    DONE = "done"
    REJECTED = "rejected"


@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => off
    top_p: float = 1.0
    max_new_tokens: int = 16
    stop_token: int | None = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]                       # token ids
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    arrival: float | None = None            # event-clock seconds; stamped at submit
    slo_ttft: float | None = None           # seconds; None = best effort
    slo_tpot: float | None = None
    # multi-model / multi-tenant identity: ``model`` names the endpoint the
    # registry routes by; ``tenant`` drives per-tenant quotas and the
    # weighted-fair scheduler.  The control plane stamps "default" when a
    # tenant is unset so metric labels never carry empty strings.
    model: str | None = None
    tenant: str | None = None
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)  # vlm patches / frames

    # --- lifecycle (engine-owned) ---
    state: State = State.QUEUED
    output: list[int] = dataclasses.field(default_factory=list)
    t_admit: float | None = None
    t_first_token: float | None = None
    t_finish: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    row: int | None = None                  # engine batch slot
    replica: int | None = None              # control-plane placement
    migrations: int = 0
    preemptions: int = 0                    # times displaced from a row pre-finish
    prefix_hit_tokens: int = 0              # prompt tokens served from KV cache
    finish_reason: str | None = None        # "stop" | "length" (OpenAI-style)

    # ------------------------------------------------------------ metrics
    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> float | None:
        """Mean time-per-output-token after the first."""
        if len(self.token_times) < 2:
            return None
        return (self.token_times[-1] - self.token_times[0]) / (len(self.token_times) - 1)

    @property
    def e2e(self) -> float | None:
        if self.t_finish is None:
            return None
        return self.t_finish - self.arrival

    def done(self) -> bool:
        return self.state in (State.DONE, State.REJECTED)

    def slo_met(self) -> bool:
        # explicit None checks: ``ttft == 0.0`` (first token in the arrival
        # step under a logical clock) and ``tpot == 0.0`` are legitimate
        # values — ``(x or default)`` would misread both as "missing"
        if self.slo_ttft is not None:
            ttft = self.ttft if self.ttft is not None else 1e30
            if ttft > self.slo_ttft:
                return False
        if self.slo_tpot is not None:
            tpot = self.tpot if self.tpot is not None else 0.0
            if tpot > self.slo_tpot:
                return False
        return True
