"""Admission control + queueing for continuous batching.

Policies:
* fcfs      — arrival order
* sjf       — shortest predicted job first (prompt length proxy)
* slo       — earliest-ttft-deadline first
* wfq       — weighted-fair across tenants (``Request.tenant``): each
              admission charges the tenant's virtual time by the request's
              token cost over its weight, and the tenant with the lowest
              virtual time always owns the next pick — under saturation,
              tenants converge to token shares proportional to their
              ``tenant_weights`` while staying FIFO within a tenant.

Admission per engine step follows Orca-style continuous batching: every
iteration, free rows are refilled from the queue (up to ``max_prefill_per
_step`` to bound prefill head-of-line blocking of running decodes).

Per-step prefill *work* is additionally bounded by ``prefill_token_budget``:
the engine passes the budget left after continuing any in-flight chunked
prefills, and :meth:`Scheduler.next_batch` admits requests in policy order
until the budget is spent (the first pick always goes through so a single
long prompt can never be starved by its own cost).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Iterable

from repro.serving.request import Request, State


@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "fcfs"            # fcfs | sjf | slo | wfq
    # "wfq": tenant -> weight (unlisted tenants weigh 1.0).  A tenant with
    # weight 3 earns ~3x the admitted tokens of a weight-1 tenant while
    # both are backlogged.
    tenant_weights: dict[str, float] | None = None
    max_queue: int = 10_000
    max_prefill_per_step: int = 4
    prefill_token_budget: int | None = None  # per-step prefilled-token cap
    admission_timeout: float | None = None   # reject if queued longer (s)
    # which token count the admission budget charges when the engine's cost
    # callable reports (padded, true) separately: "padded" = compute tokens
    # including bucket/chunk padding (what a step actually costs), "true" =
    # prompt tokens only (what the request actually needs)
    budget_counts: str = "padded"
    # SLO guard: when a running decode row's observed TPOT is at deadline
    # risk (>= slo_tpot * margin), the engine withholds *new* prefill
    # admissions, and after ``patience`` consecutive risky steps preempts
    # the freshest mid-prefill row back to the queue head — a deadline-risk
    # decode displaces a fresh prefill instead of queueing behind it
    slo_guard: bool = False
    slo_guard_margin: float = 1.0
    slo_guard_patience: int = 2


def deadline_risk(running: Iterable[Request], margin: float = 1.0) -> list[Request]:
    """Decode-phase requests whose observed TPOT is at (or past) their
    ``slo_tpot`` deadline, scaled by ``margin`` (< 1.0 flags risk *before*
    the SLO is violated).  Requests without a TPOT SLO, or without two
    tokens yet, carry no measurable risk."""
    out = []
    for r in running:
        if r.slo_tpot is None:
            continue
        tpot = r.tpot
        if tpot is not None and tpot >= r.slo_tpot * margin:
            out.append(r)
    return out


class Scheduler:
    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        assert cfg.budget_counts in ("padded", "true"), cfg.budget_counts
        self.cfg = cfg
        self.queue: deque[Request] = deque()
        self.rejected = 0
        # "wfq" state: per-tenant virtual time (service over weight).  A
        # tenant first seen mid-run starts at the *minimum* live virtual
        # time, not zero — an idle tenant must not bank credit it can later
        # spend starving everyone else.
        self._vtime: dict[str, float] = {}
        # observability hook: called as on_reject(req, now, reason) for
        # every rejection this scheduler decides ("queue-full" at submit,
        # "timeout" at admission) — the engine binds it so rejected
        # requests' traces close instead of orphaning their queue_wait span
        self.on_reject: Callable[[Request, float, str], None] | None = None

    def _reject(self, req: Request, now: float, reason: str) -> None:
        req.state = State.REJECTED
        self.rejected += 1
        if self.on_reject is not None:
            self.on_reject(req, now, reason)

    def submit(self, req: Request, now: float) -> bool:
        if len(self.queue) >= self.cfg.max_queue:
            self._reject(req, now, "queue-full")
            return False
        # ``is None`` — an explicit arrival == 0.0 is a legitimate event-clock
        # time (simulations start at t=0) and must not be overwritten.
        if req.arrival is None:
            req.arrival = now
        self.queue.append(req)
        return True

    def _key(self, r: Request, now: float):
        if self.cfg.policy == "sjf":
            return len(r.prompt)
        if self.cfg.policy == "slo":
            dl = r.arrival + (r.slo_ttft if r.slo_ttft is not None else 1e9)
            return dl
        return r.arrival

    def next_batch(self, free_slots: int, now: float,
                   budget: int | None = None,
                   cost: Callable[[Request], int] | None = None) -> list[Request]:
        """Pop up to min(free_slots, max_prefill_per_step) requests.

        ``budget`` caps the summed per-request prefill cost (tokens the engine
        will prefill for the request *this step* — bucketed length for short
        prompts, one chunk for long ones); ``cost`` maps a request to that
        number (default: prompt length), either a plain int or a
        ``(padded, true)`` pair charged per ``cfg.budget_counts`` — padded
        counts the compute the step really runs (bucket/chunk padding
        included, prefix-cached tokens excluded), true counts prompt tokens.
        The first pick is always admitted even if it alone exceeds the
        budget, so admission always progresses.
        """
        # expire
        if self.cfg.admission_timeout is not None:
            kept = deque()
            for r in self.queue:
                if now - r.arrival > self.cfg.admission_timeout:
                    self._reject(r, now, "timeout")
                else:
                    kept.append(r)
            self.queue = kept
        n = min(free_slots, self.cfg.max_prefill_per_step, len(self.queue))
        if n <= 0:
            return []
        if self.cfg.policy == "wfq":
            picked = self._wfq_pick(n, budget, cost)
            picked_set = {id(r) for r in picked}
            self.queue = deque(r for r in self.queue if id(r) not in picked_set)
            return picked
        ordered = sorted(self.queue, key=lambda r: self._key(r, now))
        if budget is None:
            picked = ordered[:n]
        else:
            picked, spent = [], 0
            idx = 1 if self.cfg.budget_counts == "true" else 0
            for r in ordered[:n]:
                c = cost(r) if cost is not None else len(r.prompt)
                if isinstance(c, tuple):
                    c = c[idx]
                if picked and spent + c > budget:
                    break
                picked.append(r)
                spent += c
        picked_set = {id(r) for r in picked}
        self.queue = deque(r for r in self.queue if id(r) not in picked_set)
        return picked

    def _wfq_pick(self, n: int,
                  budget: int | None,
                  cost: Callable[[Request], int] | None) -> list[Request]:
        """Weighted-fair selection: the backlogged tenant with the lowest
        virtual time owns each pick (FIFO within the tenant), and every
        admission advances that tenant's virtual time by the request's full
        token cost (prompt + max_new_tokens) over its weight — so under
        saturation admitted tokens converge to weight-proportional shares."""
        fifos: dict[str, deque[Request]] = {}
        for r in self.queue:
            fifos.setdefault(r.tenant or "default", deque()).append(r)
        # a tenant first seen (or returning from idle) joins at the minimum
        # live virtual time — no banked credit for having been absent
        known = [self._vtime[t] for t in fifos if t in self._vtime]
        base = min(known) if known else 0.0
        for t in fifos:
            self._vtime.setdefault(t, base)
        weights = self.cfg.tenant_weights or {}
        idx = 1 if self.cfg.budget_counts == "true" else 0
        picked: list[Request] = []
        spent = 0
        while len(picked) < n and fifos:
            t = min(fifos, key=lambda k: (self._vtime[k], fifos[k][0].arrival))
            r = fifos[t][0]
            if budget is not None:
                c = cost(r) if cost is not None else len(r.prompt)
                if isinstance(c, tuple):
                    c = c[idx]
                if picked and spent + c > budget:
                    break
                spent += c
            w = float(weights.get(t, 1.0))
            self._vtime[t] += (len(r.prompt) + r.sampling.max_new_tokens) / max(w, 1e-9)
            picked.append(r)
            fifos[t].popleft()
            if not fifos[t]:
                del fifos[t]
        return picked

    def depth(self) -> int:
        return len(self.queue)
