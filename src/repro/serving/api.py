"""OpenAI-style completions front-end over the event-driven engine.

Request/response DTOs in the shape of the ``/v1/completions`` API, a sync
path, and a streaming generator that yields one SSE-style chunk per emitted
token.  The backend is anything that speaks the serving step protocol —
the in-process :class:`~repro.serving.engine.InferenceEngine`, the
cluster :class:`~repro.core.orchestrator.Orchestrator`, or the
:class:`~repro.core.disaggregation.DisaggregatedServer`:

    submit(request, now)      admit one request
    step(now)                 one serving iteration
    drain_events() / StepStats.events    the typed per-token event stream
    pending()                 anything left to serve

Both paths are fed from the *event stream*, not from ``Request.output`` —
the response is literally the assembled stream, so sync and streaming are
equivalent by construction (and asserted so).  :class:`StreamDemux` keeps
per-request streams append-only across migrations: a successful handoff
continues at the next token index from the new replica; a rollback-requeue
re-emits earlier indices, which the demux drops.

This repo serves token ids (there is no tokenizer): ``prompt`` is a list
of ids and chunks carry ``tokens`` instead of ``text``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
from collections import deque
from typing import Any, Iterator

from repro.serving.events import (EngineEvent, FinishEvent, PreemptEvent,
                                  TokenEvent)
from repro.serving.request import Request, SamplingParams, State


def _trace_hex(rid: int) -> str:
    """Trace id for a rid (function-level import: repro.core imports this
    module, so a top-level import of repro.core.tracing would be circular).
    Response/chunk ids embed it so callers can join API output to traces."""
    from repro.core.tracing import trace_id_hex
    return trace_id_hex(rid)

# ------------------------------------------------------------------- DTOs


@dataclasses.dataclass
class CompletionRequest:
    """The ``/v1/completions`` request body (token-id variant).

    ``model`` is *required* — it is the route key: against an
    :class:`~repro.core.endpoints.EndpointRegistry` backend it selects the
    endpoint (an unknown name returns a :class:`CompletionError`, never a
    bare exception); against a single-model backend it must match the
    API's configured model name."""
    prompt: list[int]
    model: str
    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop: int | None = None          # stop token id
    stream: bool = False
    # per-request SLOs (seconds, or steps under a logical clock): drive the
    # scheduler's deadline priority / the engine's preemption guard
    slo_ttft: float | None = None
    slo_tpot: float | None = None
    # multi-tenancy: quota + weighted-fair scheduling key (None lands in
    # the "default" tenant at admission)
    tenant: str | None = None

    def to_request(self, rid: int) -> Request:
        return Request(
            rid=rid, prompt=list(self.prompt),
            sampling=SamplingParams(temperature=self.temperature,
                                    top_k=self.top_k, top_p=self.top_p,
                                    max_new_tokens=self.max_tokens,
                                    stop_token=self.stop),
            slo_ttft=self.slo_ttft, slo_tpot=self.slo_tpot,
            model=self.model, tenant=self.tenant)


@dataclasses.dataclass
class CompletionChoice:
    index: int
    tokens: list[int]
    finish_reason: str | None        # "stop" | "length" | "rejected" | None


@dataclasses.dataclass
class CompletionUsage:
    prompt_tokens: int
    completion_tokens: int
    total_tokens: int


@dataclasses.dataclass
class CompletionResponse:
    id: str
    created: float
    model: str
    choices: list[CompletionChoice]
    usage: CompletionUsage
    object: str = "text_completion"
    # per-request serving truths the OpenAI shape has no slot for — under
    # an ``x_`` extension key so the core shape stays recognisable
    x_ttft: float | None = None
    x_tpot: float | None = None
    x_migrations: int = 0
    x_trace_id: str | None = None    # join key into --trace-out output

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CompletionChunk:
    """One streamed SSE frame: a single token (or the bare finish frame)."""
    id: str
    created: float
    model: str
    choices: list[dict[str, Any]]
    object: str = "text_completion.chunk"

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def to_sse(self) -> str:
        return f"data: {json.dumps(self.to_dict())}\n\n"


SSE_DONE = "data: [DONE]\n\n"


@dataclasses.dataclass
class CompletionError:
    """OpenAI-style structured error body (``{"error": {...}}``).

    Returned (sync) or yielded as the only frame (streaming) instead of
    raising, so API consumers handle bad requests like an HTTP 4xx body
    rather than a crashed connection."""
    message: str
    type: str = "invalid_request_error"
    param: str | None = None
    code: str | None = None

    def to_dict(self) -> dict[str, Any]:
        return {"error": {"message": self.message, "type": self.type,
                          "param": self.param, "code": self.code}}

    def to_sse(self) -> str:
        return f"data: {json.dumps(self.to_dict())}\n\n"


# ---------------------------------------------------------------- models API
@dataclasses.dataclass
class ModelInfo:
    """One ``/v1/models`` entry, extended with the serving truths the
    registry knows: lifecycle state, replica count, priority class."""
    id: str
    state: str                       # "ready" | "cold" | "scaled_to_zero"
    replicas: int
    priority: int
    object: str = "model"
    owned_by: str = "repro"

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModelList:
    data: list[ModelInfo]
    object: str = "list"

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


class ModelsAPI:
    """``/v1/models``-shaped read surface over an
    :class:`~repro.core.endpoints.EndpointRegistry`."""

    def __init__(self, registry):
        self.registry = registry

    def _info(self, name: str) -> ModelInfo:
        d = self.registry.describe(name)
        return ModelInfo(id=d["name"], state=d["state"],
                         replicas=d["replicas"], priority=d["priority"])

    def list(self) -> ModelList:
        return ModelList(data=[self._info(n) for n in self.registry.names()])

    def retrieve(self, name: str) -> ModelInfo | CompletionError:
        if self.registry.resolve(name) is None:
            return CompletionError(
                message=f"model {name!r} not found; "
                        f"available: {self.registry.names()}",
                param="model", code="model_not_found")
        return self._info(name)


# ------------------------------------------------------------ demux/cursor
class StreamDemux:
    """Per-rid ordering/dedup over a merged engine event stream.

    ``feed`` returns the token events that advance each request's stream:
    index == cursor passes and advances it; index < cursor is a re-emission
    after a migration rollback and is dropped (the stream already carried
    it); index > cursor means the engine dropped a token — an invariant
    violation, raised loudly."""

    def __init__(self):
        self.cursor: dict[int, int] = {}

    def feed(self, events: list[EngineEvent]) -> list[TokenEvent]:
        out = []
        for ev in events:
            if not isinstance(ev, TokenEvent):
                continue
            c = self.cursor.get(ev.rid, 0)
            if ev.index == c:
                self.cursor[ev.rid] = c + 1
                out.append(ev)
            elif ev.index > c:
                raise RuntimeError(
                    f"stream gap for rid {ev.rid}: got index {ev.index}, "
                    f"cursor {c} — a token was dropped")
        return out

    def forget(self, rid: int) -> None:
        self.cursor.pop(rid, None)


# ---------------------------------------------------------------- frontend
class CompletionsAPI:
    """Completions front-end over one serving backend.

    ``now``/``dt``: pass ``now`` to run on a logical clock (each backend
    step advances it by ``dt``); leave it ``None`` for wall time.  Multiple
    interleaved ``stream()`` generators share the backend fairly — each
    pump fans events out to every open stream's buffer.

    Routing: a backend exposing ``resolve(name)`` (the
    :class:`~repro.core.endpoints.EndpointRegistry`) serves every model it
    knows — ``CompletionRequest.model`` picks the endpoint and an unknown
    name comes back as a :class:`CompletionError`.  Any other backend
    serves exactly one model (``model=``) and mismatches error the same
    way."""

    def __init__(self, backend, model: str = "repro-lm"):
        self.backend = backend
        self.model = model
        self._rids = itertools.count()
        self._buffers: dict[int, deque[EngineEvent]] = {}

    def _route_error(self, creq: CompletionRequest) -> CompletionError | None:
        """Structured unknown-model error, or None when routable."""
        resolve = getattr(self.backend, "resolve", None)
        if resolve is not None:
            if resolve(creq.model) is None:
                return CompletionError(
                    message=f"model {creq.model!r} not found; available: "
                            f"{self.backend.names()}",
                    param="model", code="model_not_found")
            return None
        if creq.model != self.model:
            return CompletionError(
                message=f"model {creq.model!r} not found; available: "
                        f"{[self.model]}",
                param="model", code="model_not_found")
        return None

    # ------------------------------------------------------------ plumbing
    def _pump(self, now: float | None) -> None:
        """One backend step; fan the emitted events into per-rid buffers."""
        st = self.backend.step(now)
        events = list(getattr(st, "events", None) or [])
        drain = getattr(self.backend, "drain_events", None)
        if drain is not None:
            events.extend(drain())
        for ev in events:
            if ev.rid in self._buffers:
                self._buffers[ev.rid].append(ev)

    def _submit(self, creq: CompletionRequest,
                now: float | None) -> Request:
        req = creq.to_request(next(self._rids))
        self._buffers[req.rid] = deque()
        self.backend.submit(req, now)
        return req

    def _chunk(self, req: Request, t: float, tokens: list[int],
               finish: str | None) -> CompletionChunk:
        return CompletionChunk(
            id=f"cmpl-{_trace_hex(req.rid)}", created=t,
            model=req.model or self.model,
            choices=[{"index": 0, "tokens": tokens,
                      "finish_reason": finish}])

    # ------------------------------------------------------------ sync path
    def create(self, creq: CompletionRequest, now: float | None = None,
               dt: float = 1.0,
               max_steps: int = 10_000) -> CompletionResponse | CompletionError:
        """Blocking completion: assembled from the same event stream the
        streaming path yields, then checked against ``Request.output``."""
        err = self._route_error(creq)
        if err is not None:
            return err
        t = now
        req = self._submit(creq, t)
        demux = StreamDemux()
        tokens: list[int] = []
        finish: str | None = None
        steps = 0
        try:
            while not req.done() and steps < max_steps:
                self._pump(t)
                if t is not None:
                    t += dt
                for ev in self._drain_buffer(req.rid):
                    if isinstance(ev, FinishEvent):
                        finish = ev.reason
                    else:
                        tokens.extend(tok.token for tok in demux.feed([ev]))
                steps += 1
        finally:
            self._buffers.pop(req.rid, None)
        if req.state is State.REJECTED:
            finish = "rejected"
        elif not req.done():
            raise RuntimeError(f"rid {req.rid} unfinished after "
                               f"{max_steps} steps")
        else:
            assert tokens == req.output, \
                "streamed tokens diverged from Request.output"
        created = time.time() if now is None else now
        # the response echoes the *endpoint* that served the request
        return CompletionResponse(
            id=f"cmpl-{_trace_hex(req.rid)}", created=created,
            model=creq.model,
            choices=[CompletionChoice(index=0, tokens=tokens,
                                      finish_reason=finish)],
            usage=CompletionUsage(prompt_tokens=len(creq.prompt),
                                  completion_tokens=len(tokens),
                                  total_tokens=len(creq.prompt) + len(tokens)),
            x_ttft=req.ttft, x_tpot=req.tpot, x_migrations=req.migrations,
            x_trace_id=_trace_hex(req.rid))

    # ------------------------------------------------------- streaming path
    def stream(self, creq: CompletionRequest, now: float | None = None,
               dt: float = 1.0,
               max_steps: int = 10_000) -> Iterator[CompletionChunk]:
        """Yield one chunk per emitted token, then a finish chunk.  Render
        frames with ``chunk.to_sse()`` (terminate with ``SSE_DONE``).  An
        unroutable model yields a single :class:`CompletionError` frame."""
        err = self._route_error(creq)
        if err is not None:
            yield err
            return
        t = now
        req = self._submit(creq, t)
        demux = StreamDemux()
        finish: str | None = None
        steps = 0
        try:
            while not req.done() and steps < max_steps:
                # only step the backend when this stream has nothing
                # buffered — interleaved streams pump for each other
                if not self._buffers[req.rid]:
                    self._pump(t)
                    if t is not None:
                        t += dt
                for ev in self._drain_buffer(req.rid):
                    if isinstance(ev, FinishEvent):
                        finish = ev.reason
                    elif isinstance(ev, PreemptEvent):
                        continue       # handoff/rollback: demux absorbs it
                    else:
                        for tok in demux.feed([ev]):
                            yield self._chunk(req, tok.t, [tok.token], None)
                steps += 1
            if req.state is State.REJECTED:
                finish = "rejected"
            elif not req.done():
                raise RuntimeError(f"rid {req.rid} unfinished after "
                                   f"{max_steps} steps")
            # a peer stream's pump can finish this request while this
            # generator isn't iterating — flush anything still buffered
            for ev in self._drain_buffer(req.rid):
                if isinstance(ev, FinishEvent):
                    finish = ev.reason
                elif isinstance(ev, TokenEvent):
                    for tok in demux.feed([ev]):
                        yield self._chunk(req, tok.t, [tok.token], None)
            yield self._chunk(req, req.t_finish if req.t_finish is not None
                              else (t if t is not None else time.time()),
                              [], finish or "length")
        finally:
            self._buffers.pop(req.rid, None)

    def _drain_buffer(self, rid: int) -> list[EngineEvent]:
        buf = self._buffers.get(rid)
        if not buf:
            return []
        out = list(buf)
        buf.clear()
        return out
