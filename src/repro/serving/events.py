"""Typed per-request events emitted by the serving engine.

The engine's ``step()`` no longer only returns aggregate :class:`StepStats`
— every request-visible transition is emitted as an event, so front-ends
(``serving/api.py``), the orchestrator, and benches observe per-request
truths (TTFT = the ``FirstTokenEvent`` timestamp, TPOT = gaps between
``TokenEvent`` timestamps) instead of per-step proxies.

Ordering contract:

* ``TokenEvent.index`` is the token's position in ``Request.output``.  A
  consumer tracking a per-rid cursor sees indices ``0, 1, 2, ...`` with no
  gaps.  After a migration *rollback* (the request restarted from scratch),
  already-emitted indices may be re-emitted by the re-serving replica —
  :class:`StreamDemux` in ``serving/api.py`` drops those duplicates, so a
  downstream stream is append-only with no duplicated or dropped tokens.
* ``FirstTokenEvent`` is a ``TokenEvent`` (``index == 0``): stream
  consumers handle both uniformly, latency consumers can key on the
  subclass.
* ``FinishEvent`` follows the request's last ``TokenEvent`` in the same
  step; ``reason`` mirrors the OpenAI finish reasons (``"stop"`` — stop
  token sampled, ``"length"`` — max_new_tokens or the cache row filled).
* ``PreemptEvent`` marks a request leaving its row *without* finishing:
  ``"migrate"`` (live handoff to another replica — the stream resumes from
  the destination at the next index), ``"requeued"`` (migration rollback
  failed, restarted from the queue — earlier indices will be re-emitted),
  ``"slo-decode-pressure"`` (a deadline-risk decode row displaced this
  fresh prefill; it re-enters at the queue head).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineEvent:
    t: float                    # engine step clock (wall or logical)
    rid: int


@dataclasses.dataclass(frozen=True)
class TokenEvent(EngineEvent):
    token: int
    index: int                  # position in Request.output


@dataclasses.dataclass(frozen=True)
class FirstTokenEvent(TokenEvent):
    """The request's first output token (prefill complete): its timestamp
    against ``Request.arrival`` is the per-request TTFT."""


@dataclasses.dataclass(frozen=True)
class FinishEvent(EngineEvent):
    reason: str                 # "stop" | "length"
    n_tokens: int = 0


@dataclasses.dataclass(frozen=True)
class PreemptEvent(EngineEvent):
    reason: str                 # "migrate" | "requeued" | "slo-decode-pressure"
