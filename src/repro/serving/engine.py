"""Continuous-batching inference engine (Orca-style iteration scheduling).

One engine = one model replica on one device slice.  Static shapes
throughout: a fixed decode batch of ``capacity`` rows over a ``RowPool``,
prefill bucketed to a few lengths, per-row sampling parameter vectors — so
the engine never recompiles as the request mix changes.

Prefill is a pipeline, not a one-request-at-a-time call:

* requests admitted in the same step are grouped by bucket and prefilled as
  one batched program per bucket (group size padded to a fixed power of two
  so each bucket compiles exactly once);
* prompts longer than the largest bucket are **chunked**: bucket-sized
  slices append into the row's KV/SSM cache across steps instead of raising,
  so a long prompt is a supported scenario and per-step prefill work stays
  bounded (``SchedulerConfig.prefill_token_budget``) to limit head-of-line
  blocking of running decodes.  One chunk program covers the whole pool —
  idle rows ride along as exact no-ops.

The control plane (core/) consumes the per-step telemetry this engine
emits; the same engine class serves as the *real* backend behind the
cluster simulator's cost model.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import params as P
from repro.models.lm import make_model
from repro.serving.events import (EngineEvent, FinishEvent, FirstTokenEvent,
                                  PreemptEvent, TokenEvent)
from repro.serving.kv_cache import RowPool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, State
from repro.serving.sampling import make_sampler
from repro.serving.scheduler import Scheduler, SchedulerConfig, deadline_risk


def _round_bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass
class StepStats:
    t: float
    decode_s: float
    prefill_s: float
    n_prefill: int
    occupancy: int
    queue_depth: int
    tokens_out: int
    prefill_tokens: int = 0     # prompt tokens prefilled this step (all paths)
    chunk_rows: int = 0         # rows advanced by the chunked-prefill program
    # cost-model split: what the step computed vs. what requests needed.
    # Dense charges bucket round-up / chunk slice width; the paged backend's
    # pool-wide chunk program masks rather than pads per row, so there
    # padded == true (both cache-aware: prefix hits are never charged).
    prefill_tokens_padded: int = 0  # incl. bucket round-up / chunk slice width
    prefill_tokens_true: int = 0    # actual prompt tokens advanced
    # paged-KV / prefix-cache telemetry (zero on the dense backend)
    prefix_hit_tokens: int = 0      # prompt tokens skipped at admission
    prefix_hit_rate: float = 0.0    # cumulative token hit rate
    kv_blocks_used: int = 0         # blocks referenced by live rows
    kv_blocks_cached: int = 0       # blocks retained by the prefix index
    kv_util: float = 0.0            # live-block (paged) / row (dense) fraction
    kv_frag: float = 0.0            # wasted tail-of-block slots / allocated
    # per-request events this step emitted (serving/events.py): every output
    # token, first tokens, finishes, preemptions — the streaming front-end
    # and the control plane consume these instead of per-step aggregates
    events: list[EngineEvent] = dataclasses.field(default_factory=list)
    preempted: int = 0              # rows displaced by the SLO guard this step


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *,
                 capacity: int = 8, max_len: int = 128,
                 perf: PerfConfig = BASELINE,
                 sched: SchedulerConfig = SchedulerConfig(),
                 buckets: tuple[int, ...] = (16, 32, 64),
                 kv_backend: str = "dense",
                 block_size: int = 16, num_blocks: int | None = None,
                 enable_prefix_cache: bool = True,
                 seed: int = 0, tracer=None, metrics=None):
        assert kv_backend in ("dense", "paged")
        self.cfg = cfg
        self.perf = perf
        self.model = make_model(cfg, perf)
        self.capacity = capacity
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets))
        self.chunk = self.buckets[-1]       # chunked-prefill slice length
        # chunked prefill appends at absolute text positions — it covers pure
        # decoders; vision-prefix and encoder-decoder requests stay bucketed
        self._can_chunk = not (cfg.is_encoder_decoder or cfg.num_vision_tokens)
        # paged KV backend: pure global-attention decoders only; families
        # with per-row state (SSM/conv, ring slots, enc-dec, vision prefix)
        # keep the dense RowPool backend — the engine chooses per config
        self.paged = kv_backend == "paged" and self.model.supports_paged()
        if params is None:
            params = P.init(jax.random.PRNGKey(seed), self.model.param_specs())
        self.params = params
        self.scheduler = Scheduler(sched)
        self.pool = RowPool(capacity)
        self.key = jax.random.PRNGKey(seed + 1)
        # fixed batched-prefill group size (pow2) => one compile per bucket
        g = max(1, min(capacity, sched.max_prefill_per_step))
        self._group = 1 << (g - 1).bit_length()

        # device state ------------------------------------------------------
        cache_specs = self.model.cache_specs(capacity, max_len)
        spec_leaves = jax.tree.leaves(cache_specs, is_leaf=P.is_spec)
        self._batch_axes = [s.axes.index("batch") for s in spec_leaves]
        # per-leaf reset fill (ring slot-position caches hold -1 when empty)
        self._reset_vals = [s.scale if s.init == "const" else 0.0
                            for s in spec_leaves]
        # per-leaf KV sequence axis length (None: per-row state, e.g. SSM)
        self._seq_lens = [s.shape[s.axes.index("act_kv")]
                          if "act_kv" in s.axes else None for s in spec_leaves]
        # per-leaf KV sequence axis index in the *dense* layout — the pivot
        # cross-backend payload conversion reshapes around (None: per-row
        # state with no block representation => genuinely unconvertible)
        self._seq_axes = [s.axes.index("act_kv")
                          if "act_kv" in s.axes else None for s in spec_leaves]
        if self.paged:
            self.block_size = block_size
            self.max_blk = -(-max_len // block_size)
            # default pool = the dense backend's worst-case footprint; KV is
            # *charged* per block, so idle tail blocks become prefix-cache
            # retention instead of dead per-row reservation
            self.num_blocks = (capacity * self.max_blk if num_blocks is None
                               else num_blocks)
            self.prefix = PrefixCache(self.num_blocks, block_size)
            self.prefix_enabled = enable_prefix_cache
            paged_specs = self.model.paged_cache_specs(self.num_blocks,
                                                       block_size)
            pleaves = jax.tree.leaves(paged_specs, is_leaf=P.is_spec)
            self._pool_block_axes = [s.axes.index("kv_blocks") for s in pleaves]
            self.caches = P.init(jax.random.PRNGKey(0), paged_specs)
            self.block_tables = np.full((capacity, self.max_blk), -1, np.int32)
            self._row_blocks: dict[int, list[int]] = {}
            self._row_reserved: dict[int, int] = {}
            self._reserved_total = 0
            self._hit_tokens_step = 0
        else:
            self.caches = P.init(jax.random.PRNGKey(0), cache_specs)
        self.tokens = jnp.zeros((capacity, 1), jnp.int32)
        self.pos = np.zeros((capacity,), np.int32)

        # host-side per-row bookkeeping --------------------------------------
        self.row_req: dict[int, Request] = {}
        self._temp = np.zeros((capacity,), np.float32)
        self._topk = np.zeros((capacity,), np.int32)
        self._topp = np.ones((capacity,), np.float32)
        # chunked-prefill rows: admission order preserved by dict insertion
        self._prefilling: dict[int, Request] = {}
        self._consumed: dict[int, int] = {}
        self._fresh: set[int] = set()
        self.rejected_long = 0
        # in-progress async adoptions (ticket -> reservation state): rows
        # whose KV is still streaming in over the transport.  Invisible to
        # stepping/migration — absent from row_req and _prefilling — until
        # commit_adopt activates them
        self._pending_adopt: dict[int, dict] = {}
        self._next_ticket = 0

        # jitted programs -----------------------------------------------------
        self._sampler = make_sampler()
        self._decode = jax.jit(
            lambda p, t, pos, c: self.model.decode_step(p, t, pos, c),
            donate_argnums=(3,))
        self._decode_live = jax.jit(self._decode_live_impl, donate_argnums=(3,))
        self._prefill = {}  # (bucket, group) -> jit
        self._insert = jax.jit(self._insert_rows_impl, donate_argnums=(0,))
        self._chunk_fn = None  # lazily-built chunked-prefill program
        if self.paged:
            self._decode_paged = jax.jit(
                lambda p, t, pos, c, bt, live:
                    self.model.decode_step_paged(p, t, pos, c, bt, live),
                donate_argnums=(3,))
            self._chunk_paged = jax.jit(
                lambda p, t, pos0, nv, c, bt:
                    self.model.prefill_chunk_paged(p, t, pos0, nv, c, bt),
                donate_argnums=(4,))
            self._copy_block = jax.jit(self._copy_block_impl,
                                       donate_argnums=(0,))
        self.history: list[StepStats] = []
        self.finished: list[Request] = []
        # event stream (serving/events.py): appended by every request-visible
        # transition, drained into StepStats.events at the end of each step.
        # Out-of-step emissions (migration extract/requeue between steps) are
        # picked up by the next drain — the orchestrator drains explicitly
        # after its control tick so scale-down victims' events are not lost.
        self._pending_events: list[EngineEvent] = []
        self._risk_streak = 0       # consecutive SLO-guard-risky steps
        self.preemptions = 0        # rows displaced by the SLO guard (total)

        # observability (core/tracing.py, core/metrics.py) — imported at
        # runtime: core/__init__ imports serving.engine, so a module-level
        # import here would be circular.  Standalone engines get their own
        # tracer/registry; the orchestrator and the disaggregated server
        # rebind every replica to shared ones via set_tracer/set_metrics.
        from repro.core.metrics import MetricsRegistry
        from repro.core.tracing import Tracer
        self._rlabel = str(getattr(self, "replica_label", getattr(self, "lb_id", 0)))
        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics: Any = None
        self._bind_instruments(metrics if metrics is not None
                               else MetricsRegistry())
        self.scheduler.on_reject = self._trace_reject

    # ------------------------------------------------------------- internals
    def _insert_rows_impl(self, pool_tree, new_tree, rows):
        """Scatter a batched prefill's rows into the pool along each leaf's
        batch axis.  Pad entries carry row == capacity and are dropped."""
        pl = jax.tree.leaves(pool_tree)
        nl = jax.tree.leaves(new_tree)
        out = []
        for pool, new, ax in zip(pl, nl, self._batch_axes):
            idx = (slice(None),) * ax + (rows,)
            out.append(pool.at[idx].set(new.astype(pool.dtype), mode="drop"))
        return jax.tree.unflatten(jax.tree.structure(pool_tree), out)

    def _select_rows(self, mask, true_leaves, caches):
        """Per-leaf select along each leaf's batch axis: mask rows take the
        corresponding ``true_leaves`` entry (array leaf or scalar fill)."""
        out = []
        for t, o, ax in zip(true_leaves, jax.tree.leaves(caches),
                            self._batch_axes):
            shape = [1] * o.ndim
            shape[ax] = o.shape[ax]
            t = t if hasattr(t, "ndim") else jnp.asarray(t, o.dtype)
            out.append(jnp.where(mask.reshape(shape), t, o))
        return jax.tree.unflatten(jax.tree.structure(caches), out)

    def _decode_live_impl(self, params, tokens, pos, caches, live):
        """Decode step that leaves live=False rows bit-unchanged.  Rows mid
        chunked-prefill must not take decode-step cache writes (the SSM state
        update in particular is destructive)."""
        logits, new = self.model.decode_step(params, tokens, pos, caches)
        return logits, self._select_rows(live, jax.tree.leaves(new), caches)

    def _chunk_impl(self, params, caches, tokens, pos0, n_valid, fresh):
        """One chunk for every selected pool row (n_valid==0 rows no-op).
        fresh rows are reset first — a reused row must not leak the previous
        occupant's ring positions or SSM state into a new prompt."""
        caches = self._select_rows(fresh, self._reset_vals, caches)
        return self.model.prefill_chunk(params, tokens, pos0, n_valid, caches)

    def _prefill_fn(self, bucket: int, group: int):
        key = (bucket, group)
        if key not in self._prefill:
            def fn(p, batch, true_len):
                logits, caches = self.model.prefill(p, batch, self.max_len,
                                                    true_len=true_len)
                return logits, caches
            self._prefill[key] = jax.jit(fn)
        return self._prefill[key]

    def _chunk_program(self):
        if self._chunk_fn is None:
            self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1,))
        return self._chunk_fn

    # -------------------------------------------------- paged block plumbing
    def _copy_block_impl(self, caches, src, dst):
        """Copy one KV block across every layer pool (copy-on-write)."""
        out = []
        for pool, ax in zip(jax.tree.leaves(caches), self._pool_block_axes):
            blk = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=ax)
            out.append(jax.lax.dynamic_update_slice_in_dim(pool, blk, dst,
                                                           axis=ax))
        return jax.tree.unflatten(jax.tree.structure(caches), out)

    def _blocks_horizon(self, req: Request, n_blocks_hit: int,
                        tail_hit: bool) -> int:
        """New blocks this request may still need at its peak length: total
        footprint minus cache-shared blocks, plus one CoW replacement if the
        shared tail block must be copied before the first append."""
        total = min(len(req.prompt) + req.sampling.max_new_tokens, self.max_len)
        return max(-(-total // self.block_size) - n_blocks_hit, 0) + int(tail_hit)

    def _paged_available(self) -> int:
        """Blocks a new request could still claim without over-committing:
        free + evictable-cache minus what live rows have reserved."""
        return (self.prefix.free_blocks + self.prefix.evictable_blocks
                - self._reserved_total)

    def _take_reserved(self, row: int, n: int) -> None:
        take = min(self._row_reserved.get(row, 0), n)
        if take:
            self._row_reserved[row] -= take
            self._reserved_total -= take

    def _ensure_blocks(self, row: int, upto_tokens: int) -> None:
        """Grow the row's block list to cover positions [0, upto_tokens)."""
        blocks = self._row_blocks[row]
        need = -(-upto_tokens // self.block_size) - len(blocks)
        if need <= 0:
            return
        new = self.prefix.allocate(need)
        if new is None:
            raise RuntimeError(
                f"paged KV pool exhausted: need {need} blocks, "
                f"{self.prefix.free_blocks} free / "
                f"{self.prefix.evictable_blocks} evictable "
                f"(num_blocks={self.num_blocks})")
        self.block_tables[row, len(blocks):len(blocks) + need] = new
        blocks.extend(new)
        self._take_reserved(row, need)

    def _ensure_writable(self, row: int, block_idx: int) -> None:
        """Copy-on-write: the block about to take an append may be shared
        with other rows or retained by the prefix index (a matched partial
        tail).  Writing in place would corrupt those readers, so the row
        gets a private copy first."""
        blocks = self._row_blocks[row]
        if block_idx >= len(blocks):
            return
        old = blocks[block_idx]
        if not self.prefix.needs_cow(old):
            return
        new = self.prefix.allocate(1)
        if new is None:
            raise RuntimeError("paged KV pool exhausted during copy-on-write")
        self.caches = self._copy_block(self.caches, jnp.int32(old),
                                       jnp.int32(new[0]))
        blocks[block_idx] = new[0]
        self.block_tables[row, block_idx] = new[0]
        self.prefix.decref(old)
        self.prefix.cow_copies += 1
        self._take_reserved(row, 1)

    def _release_row(self, row: int, req: Request, insert: bool) -> None:
        """Return a row's blocks: index them under the sequence's tokens
        first (so the *next* request with this prefix skips its prefill),
        then drop the row's references — cached blocks become LRU-evictable
        instead of being zeroed, uncached ones go back to the free list."""
        blocks = self._row_blocks.pop(row, None)
        if blocks is None:
            return
        if insert and self.prefix_enabled:
            n_valid = int(self.pos[row])        # KV covers positions [0, pos)
            seq = (list(req.prompt) + list(req.output))[:n_valid]
            self.prefix.insert(seq, blocks, n_valid)
        self.prefix.release(blocks)
        self.block_tables[row, :] = -1
        self._reserved_total -= self._row_reserved.pop(row, 0)

    # ------------------------------------------------------------- interface
    def submit(self, req: Request, now: float | None = None) -> bool:
        now = time.perf_counter() if now is None else now
        prefix = self.cfg.num_vision_tokens or 0
        limit = self.max_len - 1 - prefix
        if not self._can_chunk:
            limit = min(limit, self.buckets[-1])
        if len(req.prompt) > limit:
            # served-or-rejected, never a crash: a prompt that cannot fit a
            # cache row (or cannot be chunked on this family) bounces here
            req.state = State.REJECTED
            self.rejected_long += 1
            self._trace_reject(req, now, "prompt-too-long")
            return False
        if self.paged:
            total = min(len(req.prompt) + req.sampling.max_new_tokens,
                        self.max_len)
            if -(-total // self.block_size) > self.num_blocks:
                # an under-provisioned block pool can never map this request
                req.state = State.REJECTED
                self.rejected_long += 1
                self._trace_reject(req, now, "kv-unmappable")
                return False
        ok = self.scheduler.submit(req, now)
        if ok:
            self.tracer.start_trace(
                req.rid, now, replica=self._rlabel,
                prompt_tokens=len(req.prompt), slo_ttft=req.slo_ttft,
                slo_tpot=req.slo_tpot)
            # idempotent re-open: a drain/rollback resubmit of a live
            # request already has its queue_wait span running
            if self.tracer.open_span(req.rid, "queue_wait") is None:
                self.tracer.begin(req.rid, "queue_wait", now,
                                  replica=self._rlabel)
        return ok

    def pending(self) -> int:
        return self.scheduler.depth() + self.pool.used

    # --------------------------------------------------------------- prefill
    def _admit_cost(self, req: Request) -> tuple[int, int]:
        """(padded, true) prefill tokens this request consumes in its
        admission step.  Padded counts the compute actually launched (bucket
        round-up, chunk slice); true counts prompt tokens.  On the paged
        backend the cost is cache-aware: tokens whose KV the prefix cache
        already holds are never prefilled, so they cost nothing."""
        n = len(req.prompt)
        if self.paged:
            n_rem = n - (self._cached_prefix_len(req)
                         if self.prefix_enabled else 0)
            c = min(self.chunk, n_rem)
            return c, c
        if n <= self.buckets[-1]:
            return _round_bucket(n, self.buckets), n
        return self.chunk, min(self.chunk, n)

    def _cached_prefix_len(self, req: Request) -> int:
        """Memoised prefix-cache lookup: _admit_cost runs for every queued
        candidate every step, so repeat the (O(prompt) tuple-hashing) walk
        only when the index has actually changed."""
        memo = req.extras.get("_pc_lookup")
        gen = self.prefix.generation
        if memo is None or memo[0] != gen:
            memo = (gen, self.prefix.lookup(req.prompt))
            req.extras["_pc_lookup"] = memo
        return memo[1]

    def _set_row_sampling(self, row: int, req: Request) -> None:
        self._temp[row] = req.sampling.temperature
        self._topk[row] = req.sampling.top_k
        self._topp[row] = req.sampling.top_p

    def _admit_batch(self, reqs: list[Request], bucket: int, now: float) -> int:
        """Batched prefill of one bucket group: single forward, batched cache
        insertion, batched first-token sampling."""
        G = self._group
        assert len(reqs) <= G
        toks = np.zeros((G, bucket), np.int32)
        true = np.zeros((G,), np.int32)
        rows = np.full((G,), self.capacity, np.int32)   # pad => dropped
        temp = np.zeros((G,), np.float32)
        topk = np.zeros((G,), np.int32)
        topp = np.ones((G,), np.float32)
        for i, req in enumerate(reqs):
            row = self.pool.allocate(req.rid)
            assert row is not None
            req.row, req.state, req.t_admit = row, State.PREFILL, now
            self._trace_admit(req, now, kind=f"bucket{bucket}", row=row)
            self.tracer.annotate(req.rid, "prefill_chunk[0]", now,
                                 replica=self._rlabel,
                                 tokens=len(req.prompt), pos0=0)
            rows[i] = row
            toks[i, : len(req.prompt)] = req.prompt
            true[i] = len(req.prompt)
            temp[i] = req.sampling.temperature
            topk[i] = req.sampling.top_k
            topp[i] = req.sampling.top_p
        batch: dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.num_vision_tokens:
            patches = np.zeros((G, self.cfg.num_vision_tokens, self.cfg.d_model),
                               np.float32)
            for i, req in enumerate(reqs):
                if "patches" in req.extras:
                    patches[i] = np.asarray(req.extras["patches"])[0]
            batch["patches"] = jnp.asarray(patches)
        if self.cfg.is_encoder_decoder:
            frames = np.zeros((G, self.cfg.encoder_seq, self.cfg.d_model),
                              np.float32)
            for i, req in enumerate(reqs):
                if "frames" in req.extras:
                    frames[i] = np.asarray(req.extras["frames"])[0]
            batch["frames"] = jnp.asarray(frames)
        logits, row_caches = self._prefill_fn(bucket, G)(
            self.params, batch, jnp.asarray(true))
        self.caches = self._insert(self.caches, row_caches, jnp.asarray(rows))
        # batched first tokens
        self.key, sk = jax.random.split(self.key)
        sampled = self._sampler(logits.astype(jnp.float32), sk,
                                jnp.asarray(temp), jnp.asarray(topk),
                                jnp.asarray(topp))
        sampled = np.asarray(jax.device_get(sampled))
        prefix = self.cfg.num_vision_tokens or 0
        new_tokens = np.asarray(self.tokens).copy()
        for i, req in enumerate(reqs):
            t = int(sampled[i])
            row = req.row
            req.output.append(t)
            req.t_first_token = now
            req.token_times.append(now)
            req.state = State.DECODE
            self.pos[row] = len(req.prompt) + prefix
            new_tokens[row, 0] = t
            self._set_row_sampling(row, req)
            self.row_req[row] = req
            self._trace_first_token(req, now)
            self._emit_first_token(req, t, now)
            self._maybe_finish_first(row, req, now)
        self.tokens = jnp.asarray(new_tokens)
        return sum(len(r.prompt) for r in reqs)

    def _admit_chunked(self, req: Request, now: float) -> int:
        row = self.pool.allocate(req.rid)
        assert row is not None
        req.row, req.state, req.t_admit = row, State.PREFILL, now
        self._trace_admit(req, now, kind="chunked", row=row)
        self._prefilling[row] = req
        self._consumed[row] = 0
        self._fresh.add(row)
        self.pos[row] = 0
        self._set_row_sampling(row, req)
        return row

    def _admit_paged(self, req: Request, now: float) -> int | None:
        """Admit onto the paged backend (every prompt goes through the chunk
        pipeline).  The prefix cache is consulted first: matched blocks are
        mapped read-shared into the row's block table and their tokens are
        never prefilled.  Returns None — leave the request queued — when the
        block pool cannot cover the request's worst-case footprint without
        over-committing blocks other live rows may still claim."""
        blocks, n_hit, tail_hit = [], 0, False
        if self.prefix_enabled:
            blocks, n_hit = self.prefix.match(req.prompt)
            tail_hit = n_hit % self.block_size != 0
        horizon = self._blocks_horizon(req, len(blocks), tail_hit)
        if tail_hit and horizon > self._paged_available():
            # the CoW slack block can be unsatisfiable when the request's
            # footprint spans the whole pool: drop the partial-tail hit
            # (keep the aligned full-block hits) instead of deadlocking
            dropped = n_hit % self.block_size
            self.prefix.decref(blocks.pop())
            self.prefix.hit_tokens -= dropped
            self.prefix.miss_tokens += dropped
            n_hit -= dropped
            tail_hit = False
            horizon = self._blocks_horizon(req, len(blocks), False)
        if horizon > self._paged_available():
            self.prefix.release(blocks)
            # nothing was served: roll the hit/miss counters back so a
            # request retried every step doesn't inflate the reported rate
            self.prefix.hit_tokens -= n_hit
            self.prefix.miss_tokens -= len(req.prompt) - n_hit
            return None
        row = self.pool.allocate(req.rid)
        assert row is not None
        req.row, req.state, req.t_admit = row, State.PREFILL, now
        self._trace_admit(req, now, kind="paged", row=row, cached=n_hit)
        req.prefix_hit_tokens = n_hit
        self._row_blocks[row] = list(blocks)
        self.block_tables[row, :] = -1
        self.block_tables[row, :len(blocks)] = blocks
        self._row_reserved[row] = horizon
        self._reserved_total += horizon
        self._prefilling[row] = req
        self._consumed[row] = n_hit          # cached tokens: already prefilled
        self.pos[row] = n_hit
        self._set_row_sampling(row, req)
        self._hit_tokens_step += n_hit
        return row

    def _run_chunks(self, rows_n: dict[int, int], now: float) -> None:
        """Advance the selected mid-prefill rows by one chunk each (single
        pool-wide program call); promote rows that consumed their prompt."""
        B, C = self.capacity, self.chunk
        toks = np.zeros((B, C), np.int32)
        pos0 = np.zeros((B,), np.int32)
        nval = np.zeros((B,), np.int32)
        fresh = np.zeros((B,), bool)
        for row, n in rows_n.items():
            req = self._prefilling[row]
            c0 = self._consumed[row]
            k = self.tracer.count(req.rid, "prefill_chunk")
            self.tracer.annotate(req.rid, f"prefill_chunk[{k}]", now,
                                 replica=self._rlabel, tokens=n, pos0=c0)
            toks[row, :n] = req.prompt[c0:c0 + n]
            pos0[row] = c0
            nval[row] = n
            fresh[row] = row in self._fresh
            if self.paged:
                # map blocks for this chunk's span; CoW a shared first block
                self._ensure_blocks(row, c0 + n)
                self._ensure_writable(row, c0 // self.block_size)
        if self.paged:
            logits, self.caches = self._chunk_paged(
                self.params, jnp.asarray(toks), jnp.asarray(pos0),
                jnp.asarray(nval), self.caches,
                jnp.asarray(self.block_tables))
        else:
            logits, self.caches = self._chunk_program()(
                self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos0),
                jnp.asarray(nval), jnp.asarray(fresh))
        self._fresh -= set(rows_n)
        done_rows = []
        for row, n in rows_n.items():
            self._consumed[row] += n
            self.pos[row] = self._consumed[row]
            if self._consumed[row] >= len(self._prefilling[row].prompt):
                done_rows.append(row)
        if not done_rows:
            return
        self.key, sk = jax.random.split(self.key)
        sampled = self._sampler(logits.astype(jnp.float32), sk,
                                jnp.asarray(self._temp), jnp.asarray(self._topk),
                                jnp.asarray(self._topp))
        sampled = np.asarray(jax.device_get(sampled))
        new_tokens = np.asarray(self.tokens).copy()
        for row in done_rows:
            req = self._prefilling.pop(row)
            del self._consumed[row]
            t = int(sampled[row])
            req.output.append(t)
            # a migrated-in decode-phase row resuming here never re-samples
            # its first token; chunk completions are always first tokens
            req.t_first_token = now
            req.token_times.append(now)
            req.state = State.DECODE
            self.pos[row] = len(req.prompt)
            new_tokens[row, 0] = t
            self.row_req[row] = req
            self._trace_first_token(req, now)
            self._emit_first_token(req, t, now)
            self._maybe_finish_first(row, req, now)
        self.tokens = jnp.asarray(new_tokens)

    def _maybe_finish_first(self, row: int, req: Request, now: float) -> None:
        """A request can already be complete at its first (prefill) token —
        max_new_tokens=1, stop token sampled, or a prompt filling the row —
        in which case it must not receive a same-step decode token."""
        stop = req.sampling.stop_token
        if (len(req.output) >= req.sampling.max_new_tokens
                or (stop is not None and req.output[-1] == stop)
                or self.pos[row] >= self.max_len - 1):
            self._retire(row, now)

    def _retire(self, row: int, now: float) -> None:
        req = self.row_req.pop(row)
        req.state = State.DONE
        req.t_finish = now
        req.row = None
        stop = req.sampling.stop_token
        req.finish_reason = ("stop" if stop is not None and req.output
                             and req.output[-1] == stop else "length")
        if self.paged:
            self._release_row(row, req, insert=True)
        self.pool.free(row)
        self.finished.append(req)
        self.tracer.end(req.rid, "decode", now, tokens=len(req.output))
        self.tracer.finish(req.rid, now)
        self.emit_event(FinishEvent(t=now, rid=req.rid,
                                    reason=req.finish_reason,
                                    n_tokens=len(req.output)))

    # ------------------------------------------------------------- events
    def emit_event(self, ev: EngineEvent) -> None:
        """Append to the engine's event stream (drained into the next
        ``StepStats.events``).  Public so the migration layer can record
        handoff/rollback transitions against the engine they happened on."""
        self._pending_events.append(ev)
        # central count: every preempt/finish flows through here, including
        # the ones the migration layer emits between steps
        if isinstance(ev, PreemptEvent):
            self._c_preempts.inc(replica=self._rlabel, reason=ev.reason)
        elif isinstance(ev, FinishEvent):
            self._c_finished.inc(replica=self._rlabel, reason=ev.reason)

    def drain_events(self) -> list[EngineEvent]:
        """Return and clear the pending event stream.  ``step()`` drains
        into its StepStats; callers that mutate the engine *between* steps
        (migration, scale-down drains) drain explicitly afterwards."""
        ev, self._pending_events = self._pending_events, []
        return ev

    def _emit_first_token(self, req: Request, token: int, now: float) -> None:
        self.emit_event(FirstTokenEvent(t=now, rid=req.rid, token=token,
                                        index=0))

    # ------------------------------------------------------- observability
    def set_tracer(self, tracer) -> None:
        """Rebind to a shared (cluster-wide) tracer; also refreshes the
        replica label, which the control plane sets via ``lb_id``."""
        self.tracer = tracer
        self._rlabel = str(getattr(self, "replica_label", getattr(self, "lb_id", 0)))

    def set_metrics(self, registry) -> None:
        """Rebind every instrument onto a shared (cluster-wide) registry."""
        self._bind_instruments(registry)

    def _bind_instruments(self, registry) -> None:
        self.metrics = registry
        self._rlabel = str(getattr(self, "replica_label", getattr(self, "lb_id", 0)))
        self._c_prefill_tok = registry.counter(
            "engine_prefill_tokens_total",
            "Prompt tokens prefilled (true) / compute launched (padded)",
            ("replica", "kind"))
        self._c_decode_tok = registry.counter(
            "engine_decode_tokens_total", "Decode tokens emitted", ("replica",))
        self._c_admissions = registry.counter(
            "engine_admissions_total", "Requests admitted onto a row",
            ("replica",))
        self._c_finished = registry.counter(
            "engine_requests_finished_total", "Requests retired, by reason",
            ("replica", "reason"))
        self._c_preempts = registry.counter(
            "engine_preemptions_total",
            "Rows displaced pre-finish, by reason (slo-decode-pressure / "
            "migrate / requeued)", ("replica", "reason"))
        self._c_rejections = registry.counter(
            "serving_rejections_total",
            "Requests rejected, by reason (queue-full / timeout / "
            "prompt-too-long / kv-unmappable)", ("replica", "reason"))
        self._g_occupancy = registry.gauge(
            "engine_batch_occupancy", "Rows occupied / capacity", ("replica",))
        self._g_queue = registry.gauge(
            "engine_queue_depth", "Scheduler queue depth", ("replica",))
        self._g_kv_util = registry.gauge(
            "engine_kv_util", "KV memory utilization fraction", ("replica",))
        self._g_kv_frag = registry.gauge(
            "engine_kv_frag", "Wasted tail-of-block KV slots fraction",
            ("replica",))
        self._h_step = registry.histogram(
            "engine_step_seconds", "Wall seconds per step phase",
            ("replica", "phase"))
        if self.paged:
            self._c_prefix = registry.counter(
                "prefix_cache_tokens_total",
                "Prefix-cache token outcomes (hit / miss)",
                ("replica", "kind"))
            self._c_prefix_ev = registry.counter(
                "prefix_cache_events_total",
                "Prefix-cache block events (evictions / cow_copies / "
                "inserted_blocks)", ("replica", "kind"))
            self._g_blocks = registry.gauge(
                "prefix_cache_blocks", "KV blocks by state (used / cached)",
                ("replica", "kind"))

    def _observe_step(self, st: StepStats) -> None:
        """Mirror one StepStats into the registry (never affects serving)."""
        rl = self._rlabel
        if st.prefill_tokens:
            self._c_prefill_tok.inc(st.prefill_tokens_true, replica=rl,
                                    kind="true")
            self._c_prefill_tok.inc(st.prefill_tokens_padded, replica=rl,
                                    kind="padded")
            self._h_step.observe(st.prefill_s, replica=rl, phase="prefill")
        if st.tokens_out:
            self._c_decode_tok.inc(st.tokens_out, replica=rl)
            self._h_step.observe(st.decode_s, replica=rl, phase="decode")
        if st.n_prefill:
            self._c_admissions.inc(st.n_prefill, replica=rl)
        self._g_occupancy.set(st.occupancy / max(self.capacity, 1), replica=rl)
        self._g_queue.set(st.queue_depth, replica=rl)
        self._g_kv_util.set(st.kv_util, replica=rl)
        self._g_kv_frag.set(st.kv_frag, replica=rl)
        if self.paged:
            # peg, not inc: the prefix cache keeps its own cumulative
            # counters, and a re-bound registry must not double count
            self._c_prefix.peg(self.prefix.hit_tokens, replica=rl, kind="hit")
            self._c_prefix.peg(self.prefix.miss_tokens, replica=rl,
                               kind="miss")
            self._c_prefix_ev.peg(self.prefix.evictions, replica=rl,
                                  kind="evictions")
            self._c_prefix_ev.peg(self.prefix.cow_copies, replica=rl,
                                  kind="cow_copies")
            self._c_prefix_ev.peg(self.prefix.inserted_blocks, replica=rl,
                                  kind="inserted_blocks")
            self._g_blocks.set(st.kv_blocks_used, replica=rl, kind="used")
            self._g_blocks.set(st.kv_blocks_cached, replica=rl, kind="cached")

    def _trace_reject(self, req: Request, now: float, reason: str) -> None:
        """Rejection: a complete (instant) trace plus the rejection counter.
        Doubles as the scheduler's ``on_reject`` hook, so queue-full and
        admission-timeout rejections close their queue_wait span instead of
        orphaning it."""
        self._c_rejections.inc(replica=self._rlabel, reason=reason)
        self.tracer.start_trace(req.rid, now, replica=self._rlabel,
                                prompt_tokens=len(req.prompt))
        self.tracer.finish(req.rid, now, status=f"rejected:{reason}")

    def _trace_admit(self, req: Request, now: float, *, kind: str, row: int,
                     cached: int = 0) -> None:
        """Queue residency ends, prefill phase opens."""
        tr, rid, rl = self.tracer, req.rid, self._rlabel
        tr.end(rid, "queue_wait", now)
        tr.annotate(rid, "admission", now, replica=rl, row=row, kind=kind,
                    cached_prefix_tokens=cached)
        tr.begin(rid, "prefill", now, replica=rl,
                 prompt_tokens=len(req.prompt), cached_prefix_tokens=cached)

    def _trace_first_token(self, req: Request, now: float) -> None:
        """Prefill phase closes at the first token; decode phase opens."""
        self.tracer.end(req.rid, "prefill", now)
        self.tracer.begin(req.rid, "decode", now, replica=self._rlabel)

    # --------------------------------------------------------- SLO preempt
    def _preempt_freshest_prefill(self, now: float) -> bool:
        """Displace the most recently admitted mid-prefill row back to the
        queue head (deadline-risk decode rows outrank fresh prefill work).
        On the paged backend its consumed-prefix blocks are donated to the
        prefix index first, so re-admission is mostly cache hits; a dense
        row restarts its prefill from scratch."""
        if not self._prefilling:
            return False
        row = next(reversed(self._prefilling))      # insertion order = age
        req = self._prefilling.pop(row)
        self._consumed.pop(row, None)
        self._fresh.discard(row)
        if self.paged:
            self._release_row(row, req, insert=True)
        self.pool.free(row)
        self.pos[row] = 0
        req.state = State.QUEUED
        req.row = None
        req.t_admit = None
        req.preemptions += 1
        self.preemptions += 1
        self.scheduler.queue.appendleft(req)
        self.tracer.end(req.rid, "prefill", now, status="preempted")
        self.tracer.annotate(req.rid, "slo_guard_preempt", now,
                             replica=self._rlabel)
        self.tracer.begin(req.rid, "queue_wait", now, replica=self._rlabel,
                          requeued=True)
        self.emit_event(PreemptEvent(t=now, rid=req.rid,
                                     reason="slo-decode-pressure"))
        return True

    # ------------------------------------------------------------------ step
    def step(self, now: float | None = None) -> StepStats:
        """One engine iteration: chunk continuations -> admit (batched
        bucket prefills + new chunk starts) -> one decode step."""
        now = time.perf_counter() if now is None else now
        t0 = time.perf_counter()
        budget = self.scheduler.cfg.prefill_token_budget
        # a non-positive budget would starve admission forever; clamp to the
        # minimum that still guarantees one (over-budget) pick per step
        remaining = math.inf if budget is None else max(budget, 1)
        prefill_tokens = 0
        prefill_padded = 0
        if self.paged:
            self._hit_tokens_step = 0

        # 0. SLO guard: decode rows at TPOT-deadline risk displace fresh
        # prefill work — no new admissions while any row is at risk, and a
        # persistent streak preempts the freshest mid-prefill row so the
        # next steps' chunk work shrinks
        scfg = self.scheduler.cfg
        at_risk: list[Request] = []
        preempted = 0
        if scfg.slo_guard:
            at_risk = deadline_risk(self.row_req.values(),
                                    scfg.slo_guard_margin)
            self._risk_streak = self._risk_streak + 1 if at_risk else 0
            if at_risk and self._risk_streak >= scfg.slo_guard_patience:
                if self._preempt_freshest_prefill(now):
                    preempted = 1

        # 1. continue in-flight chunked prefills (admission order); the
        # oldest row always advances so progress is never starved
        rows_n: dict[int, int] = {}
        for row, req in self._prefilling.items():
            n = min(self.chunk, len(req.prompt) - self._consumed[row])
            if rows_n and remaining < n:
                continue
            rows_n[row] = n
            remaining -= n
            prefill_tokens += n
            prefill_padded += n if self.paged else self.chunk

        # 2. admission under the remaining budget (withheld entirely while
        # the SLO guard sees deadline-risk decode rows)
        incoming: list[Request] = []
        if remaining > 0 and not at_risk:
            free = self.capacity - self.pool.used
            incoming = self.scheduler.next_batch(
                free, now, budget=None if budget is None else int(remaining),
                cost=self._admit_cost)
        groups: dict[int, list[Request]] = {}
        admitted = 0
        for i, req in enumerate(incoming):
            n = len(req.prompt)
            if self.paged:
                row = self._admit_paged(req, now)
                if row is None:
                    # KV blocks exhausted: requeue (FCFS order preserved)
                    # and stop admitting until blocks free up
                    for r in reversed(incoming[i:]):
                        self.scheduler.queue.appendleft(r)
                    break
                rows_n[row] = min(self.chunk, n - self._consumed[row])
                prefill_tokens += rows_n[row]
                prefill_padded += rows_n[row]
                admitted += 1
            elif n <= self.buckets[-1]:
                groups.setdefault(_round_bucket(n, self.buckets), []).append(req)
                admitted += 1
            elif self._can_chunk:
                row = self._admit_chunked(req, now)
                rows_n[row] = min(self.chunk, n)
                prefill_tokens += rows_n[row]
                prefill_padded += self.chunk
                admitted += 1
            else:  # belt-and-braces: submit() already bounces these
                req.state = State.REJECTED
                self.rejected_long += 1
        for bucket in sorted(groups):
            prefill_tokens += self._admit_batch(groups[bucket], bucket, now)
            prefill_padded += bucket * len(groups[bucket])

        # 3. one pool-wide chunk program for all advancing rows
        if rows_n:
            self._run_chunks(rows_n, now)
        t_pre = time.perf_counter() - t0

        # 4. decode
        tokens_out = 0
        t_dec = 0.0
        if self.row_req:
            t0 = time.perf_counter()
            if self.paged:
                # map the block each row's next token lands in (CoW'd if the
                # prefix cache or another row still reads it); dead rows are
                # masked so their writes drop instead of corrupting blocks
                live = np.zeros((self.capacity,), bool)
                for row in self.row_req:
                    live[row] = True
                    self._ensure_blocks(row, int(self.pos[row]) + 1)
                    self._ensure_writable(
                        row, int(self.pos[row]) // self.block_size)
                logits, self.caches = self._decode_paged(
                    self.params, self.tokens, jnp.asarray(self.pos),
                    self.caches, jnp.asarray(self.block_tables),
                    jnp.asarray(live))
            elif self._prefilling:
                live = np.ones((self.capacity,), bool)
                for row in self._prefilling:
                    live[row] = False
                logits, self.caches = self._decode_live(
                    self.params, self.tokens, jnp.asarray(self.pos),
                    self.caches, jnp.asarray(live))
            else:
                logits, self.caches = self._decode(
                    self.params, self.tokens, jnp.asarray(self.pos),
                    self.caches)
            self.key, sk = jax.random.split(self.key)
            sampled = self._sampler(logits.astype(jnp.float32), sk,
                                    jnp.asarray(self._temp), jnp.asarray(self._topk),
                                    jnp.asarray(self._topp))
            sampled = np.asarray(jax.device_get(sampled))
            t_dec = time.perf_counter() - t0
            new_tokens = np.asarray(self.tokens).copy()
            for row, req in list(self.row_req.items()):
                t = int(sampled[row])
                req.output.append(t)
                req.token_times.append(now)
                tokens_out += 1
                self.pos[row] += 1
                new_tokens[row, 0] = t
                self.emit_event(TokenEvent(t=now, rid=req.rid, token=t,
                                           index=len(req.output) - 1))
                stop = req.sampling.stop_token
                if (len(req.output) >= req.sampling.max_new_tokens
                        or (stop is not None and t == stop)
                        or self.pos[row] >= self.max_len - 1):
                    self._retire(row, now)
            self.tokens = jnp.asarray(new_tokens)

        st = StepStats(t=now, decode_s=t_dec, prefill_s=t_pre,
                       n_prefill=admitted, occupancy=self.pool.used,
                       queue_depth=self.scheduler.depth(), tokens_out=tokens_out,
                       prefill_tokens=prefill_tokens, chunk_rows=len(rows_n),
                       prefill_tokens_padded=prefill_padded,
                       prefill_tokens_true=prefill_tokens,
                       events=self.drain_events(), preempted=preempted)
        if self.paged:
            alloc = sum(len(b) for b in self._row_blocks.values()) \
                * self.block_size
            live_tok = int(sum(int(self.pos[r]) for r in self._row_blocks))
            st.prefix_hit_tokens = self._hit_tokens_step
            st.prefix_hit_rate = self.prefix.hit_rate()
            st.kv_blocks_used = self.prefix.used_blocks
            st.kv_blocks_cached = self.prefix.cached_blocks
            st.kv_util = self.prefix.utilization()
            st.kv_frag = 0.0 if alloc == 0 else 1.0 - live_tok / alloc
        else:
            st.kv_util = self.pool.utilization()
        self._observe_step(st)
        self.history.append(st)
        return st

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # --------------------------------------------------------- migration
    def _find_row(self, rid: int) -> tuple[int, Request, str]:
        """Locate a live request by rid: (row, request, phase) where phase
        is "decode" (prefill complete) or "prefill" (mid-chunked-prefill,
        extractable at its current chunk boundary)."""
        for row, q in self.row_req.items():
            if q.rid == rid:
                return row, q, "decode"
        for row, q in self._prefilling.items():
            if q.rid == rid:
                return row, q, "prefill"
        raise KeyError(f"rid {rid} not active here")

    def migratable_requests(self) -> list[Request]:
        """Live requests a migration payload can be built for: every decode
        row, plus mid-prefill rows that have consumed at least one chunk
        (a consumed==0 dense row has not run its cache reset yet — there is
        nothing coherent to extract, only a request to requeue)."""
        out = list(self.row_req.values())
        out += [q for row, q in self._prefilling.items()
                if self._consumed.get(row, 0) > 0]
        return out

    def migration_sequence(self, rid: int) -> list[int]:
        """Tokens whose KV is materialised for this request — what a
        destination's prefix cache can be probed with before transfer."""
        row, req, _ = self._find_row(rid)
        n = int(self.pos[row])
        return (list(req.prompt) + list(req.output))[:n]

    def can_adopt(self, req: Request, n_valid: int,
                  n_keep_blocks: int = 0) -> bool:
        """Cheap adopt admissibility probe — no row taken, no cache data
        touched, no refcounts moved.  Lets the migration layer skip a
        target without paying for a full extract/rollback round-trip.
        ``n_keep_blocks``: full blocks this engine's prefix cache already
        holds for the sequence (it would reuse, not re-allocate, them)."""
        if self.pool.used >= self.capacity:
            return False
        if not self.paged:
            return True
        n_total = -(-n_valid // self.block_size)
        future = self._blocks_horizon(req, n_total, False)
        return (n_total - n_keep_blocks) + future <= self._paged_available()

    def kv_per_block_bytes(self) -> int:
        """Bytes one KV block holds across every layer pool (paged only)."""
        assert self.paged
        return sum(pool.nbytes // pool.shape[ax]
                   for pool, ax in zip(jax.tree.leaves(self.caches),
                                       self._pool_block_axes))

    def _gather_blocks(self, block_ids: list[int]):
        """Per-layer (n_blocks, block_size, ...) slabs for the given pool
        blocks — the data plane of a paged migration payload."""
        ids = jnp.asarray(block_ids, jnp.int32)
        leaves = [jnp.take(pool, ids, axis=ax)
                  for pool, ax in zip(jax.tree.leaves(self.caches),
                                      self._pool_block_axes)]
        return jax.tree.unflatten(jax.tree.structure(self.caches), leaves)

    def _scatter_blocks(self, data, block_ids: list[int], lo: int) -> None:
        """Write payload slabs (skipping the first ``lo`` blocks — the
        destination already holds them) into the given fresh pool blocks."""
        if not block_ids:
            return
        ids = jnp.asarray(block_ids, jnp.int32)
        out = []
        for pool, d, ax in zip(jax.tree.leaves(self.caches),
                               jax.tree.leaves(data), self._pool_block_axes):
            sl = jax.lax.slice_in_dim(d, lo, d.shape[ax], axis=ax)
            idx = (slice(None),) * ax + (ids,)
            out.append(pool.at[idx].set(sl.astype(pool.dtype)))
        self.caches = jax.tree.unflatten(jax.tree.structure(self.caches), out)

    def extract_row(self, rid: int, now: float | None = None):
        """Remove a live request, returning its migration payload
        (Llumnix-style pause-and-copy handoff).  Works for decode rows and
        for mid-chunked-prefill rows at their current chunk boundary — the
        payload carries the prefill progress (``phase``/``pos``) so the
        destination resumes exactly where the source stopped.

        Dense payload: the row's cache tree sliced to batch dim 1.  Paged
        payload: per-layer (n_blocks, block_size, ...) slabs for the mapped
        blocks plus the token sequence they hold, so the destination can
        re-allocate through its own PrefixCache and skip blocks it already
        caches.  The source row is freed; its blocks are donated to the
        source's prefix index first, so a rollback re-adopt (or the next
        request with this prefix) is mostly cache hits."""
        row, req, phase = self._find_row(rid)
        if phase == "prefill" and self._consumed.get(row, 0) <= 0:
            raise ValueError(f"rid {rid} has not completed a chunk yet — "
                             "requeue it instead of migrating")
        n_valid = int(self.pos[row])
        payload: dict[str, Any] = {"pos": n_valid, "phase": phase}
        if phase == "decode":
            payload["last_token"] = int(np.asarray(self.tokens)[row, 0])
        if self.paged:
            blocks = self._row_blocks[row][: -(-n_valid // self.block_size)]
            payload["kind"] = "paged"
            payload["seq"] = self.migration_sequence(rid)
            payload["blocks"] = self._gather_blocks(blocks)
            payload["n_blocks"] = len(blocks)
        else:
            leaves = jax.tree.leaves(self.caches)
            sliced = [jax.lax.dynamic_slice_in_dim(pool, row, 1, axis=ax)
                      for pool, ax in zip(leaves, self._batch_axes)]
            payload["kind"] = "dense"
            payload["caches"] = jax.tree.unflatten(
                jax.tree.structure(self.caches), sliced)
        if phase == "decode":
            del self.row_req[row]
        else:
            del self._prefilling[row]
            del self._consumed[row]
            self._fresh.discard(row)
        if self.paged:
            self._release_row(row, req, insert=True)
        req.state = State.MIGRATING
        req.row = None
        req.migrations += 1
        self.pool.free(row)
        now = time.perf_counter() if now is None else now
        # close this replica's slice of the phase span and ship the span
        # context with the KV: the destination continues the same trace
        self.tracer.end(rid, "decode" if phase == "decode" else "prefill",
                        now, status="migrate-out")
        payload["trace"] = self.tracer.export_context(rid)
        self.emit_event(PreemptEvent(t=now, rid=rid, reason="migrate"))
        return req, payload

    def begin_adopt(self, req: Request, payload: dict,
                    now: float | None = None) -> int | None:
        """Reserve everything an incoming migration needs *before* any KV
        lands: a batch row and, on the paged backend, the full block plan —
        destination-cached full blocks are reused (their refcounts pin them
        against eviction for the transfer's whole flight), fresh blocks are
        allocated through the prefix cache with the same reservation-based
        admission as ``_admit_paged``, so an adoption that starts can always
        grow to the request's peak length without deadlocking the pool.

        Returns an opaque ticket for ``feed_adopt``/``commit_adopt``/
        ``abort_adopt``, or None when no row or no admissible block plan is
        available (nothing reserved — the caller rolls back at the source).
        The pending row is invisible to stepping and migration (absent from
        ``row_req`` and ``_prefilling``) until commit activates it."""
        kind = payload.get("kind", "dense")
        want = "paged" if self.paged else "dense"
        if kind != want:
            raise ValueError(f"cannot adopt a {kind!r} payload on a {want!r} "
                             "engine — convert the payload first "
                             "(convert_payload) or migrate same-backend")
        row = self.pool.allocate(req.rid)
        if row is None:
            return None
        st: dict[str, Any] = {"req": req, "row": row, "payload": payload,
                              "n_keep": 0, "blocks": None, "chunks": {},
                              "expected": 1}
        if self.paged:
            seq, n_valid = payload["seq"], payload["pos"]
            n_total = -(-n_valid // self.block_size)
            future = self._blocks_horizon(req, n_total, False)
            if self.prefix_enabled:
                plan = self.prefix.adopt_blocks(seq, n_valid, future,
                                                self._reserved_total)
            else:
                plan = None
                if n_total + future <= self._paged_available():
                    got = self.prefix.allocate(n_total)
                    plan = (got, 0) if got is not None else None
            if plan is None:
                self.pool.free(row)
                return None
            blocks, n_keep = plan
            self._row_blocks[row] = blocks
            self.block_tables[row, :] = -1
            self.block_tables[row, : len(blocks)] = blocks
            self._row_reserved[row] = future
            self._reserved_total += future
            st["blocks"], st["n_keep"] = blocks, n_keep
            # one transfer chunk per block the destination doesn't hold (the
            # reused prefix blocks never cross the wire); adopt_blocks
            # guarantees the tail block is fresh, so expected >= 1
            st["expected"] = payload["n_blocks"] - n_keep
        self.pos[row] = 0          # no live tokens until commit
        self._next_ticket += 1
        self._pending_adopt[self._next_ticket] = st
        return self._next_ticket

    def feed_adopt(self, ticket: int, index: int, data) -> None:
        """Land one transfer chunk of an in-progress adoption.  Paged:
        ``data`` is the per-layer single-block slab for payload block
        ``n_keep + index``, scattered straight into the reserved pool block
        (chunks may arrive in any order; duplicates are ignored).  Dense:
        the single full-row cache tree, buffered host-side — the device
        scatter happens at commit so an in-flight transfer never races the
        whole-batch decode writes."""
        st = self._pending_adopt[ticket]
        if index in st["chunks"]:
            return
        if self.paged:
            block = st["blocks"][st["n_keep"] + index]
            self._scatter_blocks(data, [block], 0)
            st["chunks"][index] = True
        else:
            st["chunks"][index] = data

    def commit_adopt(self, ticket: int, now: float | None = None) -> Request:
        """Activate a fully-transferred adoption: donate the request's full
        blocks into the radix index (their positions are immutable now — the
        partial tail stays private so the row's own appends never trigger a
        copy-on-write), restore position/sampling state, continue the
        request's trace here, and make the row live for the next step."""
        now = time.perf_counter() if now is None else now
        st = self._pending_adopt.pop(ticket)
        req, row, payload = st["req"], st["row"], st["payload"]
        assert len(st["chunks"]) >= st["expected"], \
            "commit_adopt before every chunk landed"
        if self.paged:
            seq, n_valid = payload["seq"], payload["pos"]
            if self.prefix_enabled:
                self.prefix.insert(seq, st["blocks"],
                                   (n_valid // self.block_size)
                                   * self.block_size)
            req.extras["adopt_hit_blocks"] = st["n_keep"]
        else:
            self.caches = self._insert(self.caches, st["chunks"][0],
                                       jnp.asarray([row], jnp.int32))
        self.pos[row] = payload["pos"]
        self._set_row_sampling(row, req)
        req.row = row
        # continue the request's trace here: same trace id, span ids offset
        # past the source's (no-op import when the cluster shares a tracer)
        self.tracer.import_context(payload.get("trace"))
        if payload["phase"] == "decode":
            self.tokens = self.tokens.at[row, 0].set(payload["last_token"])
            self.row_req[row] = req
            req.state = State.DECODE
            self.tracer.begin(req.rid, "decode", now, replica=self._rlabel,
                              migrated_in=True, resume_pos=payload["pos"])
        else:
            # mid-prefill handoff: resume the chunk pipeline at the boundary
            self._prefilling[row] = req
            self._consumed[row] = payload["pos"]
            req.state = State.PREFILL
            self.tracer.begin(req.rid, "prefill", now, replica=self._rlabel,
                              migrated_in=True, resume_pos=payload["pos"])
        return req

    def abort_adopt(self, ticket: int) -> None:
        """Drop an in-progress adoption and return every reservation."""
        st = self._pending_adopt.pop(ticket)
        if self.paged:
            self._release_row(st["row"], st["req"], insert=False)
        self.pool.free(st["row"])

    def adopt(self, req: Request, payload: dict, now: float | None = None) -> bool:
        """Install a migrated request synchronously (cache shapes must
        match: same cfg, capacity-independent, same max_len/block_size; use
        ``convert_payload`` across KV backends).  Returns False — leaving
        this engine untouched — when no row or, on the paged backend, no
        admissible block plan is available.

        Expressed as begin/feed-all/commit so the synchronous path and the
        transport's block-granular async path share one implementation —
        which is what makes them token-identical by construction."""
        now = time.perf_counter() if now is None else now
        ticket = self.begin_adopt(req, payload, now)
        if ticket is None:
            return False
        st = self._pending_adopt[ticket]
        if self.paged:
            # one-shot scatter of the whole slab, skipping reused blocks
            self._scatter_blocks(payload["blocks"],
                                 st["blocks"][st["n_keep"]:], st["n_keep"])
            st["chunks"] = {i: True for i in range(st["expected"])}
        else:
            st["chunks"][0] = payload["caches"]
        self.commit_adopt(ticket, now)
        return True

    # --------------------------------------- cross-backend payload conversion
    def can_convert(self, other) -> bool:
        """Whether a migration payload from ``other`` (the opposite KV
        backend) is convertible to this engine's layout.  Genuinely
        unservable shapes — any cache leaf without a KV sequence axis
        (SSM state, conv tails, ring buffers: no block representation) —
        are the one case the migration layer still records as a
        ``backend-mismatch`` failure."""
        return (self.model.supports_paged()
                and other.model.supports_paged()
                and self.max_len == other.max_len
                and not any(ax is None for ax in self._seq_axes))

    def convert_payload(self, req: Request, payload: dict) -> dict | None:
        """Rebuild a migration payload from the other KV backend into this
        engine's layout, leaf by leaf (dense and paged cache trees mirror
        each other: the block axis sits where the batch axis was, the slot
        axis where the sequence axis was).  Paged -> dense flattens block
        slabs back into one padded row; dense -> paged slices the row into
        ``block_size`` slots.  Positions past ``pos`` are zero-padding the
        decode mask never reads.  Returns None for shapes ``can_convert``
        rejects."""
        kind = payload.get("kind", "dense")
        want = "paged" if self.paged else "dense"
        if kind == want:
            return payload
        if (any(ax is None for ax in self._seq_axes)
                or not self.model.supports_paged()):
            return None
        pos = payload["pos"]
        out = {k: v for k, v in payload.items()
               if k not in ("kind", "seq", "blocks", "n_blocks", "caches")}
        out["kind"] = want
        if want == "dense":
            leaves = []
            for d, ax, L in zip(jax.tree.leaves(payload["blocks"]),
                                self._batch_axes, self._seq_lens):
                nb, slot = d.shape[ax], d.shape[ax + 1]
                x = d.reshape(d.shape[:ax] + (nb * slot,) + d.shape[ax + 2:])
                if nb * slot < L:
                    pad = [(0, 0)] * x.ndim
                    pad[ax] = (0, L - nb * slot)
                    x = jnp.pad(x, pad)
                else:
                    x = jax.lax.slice_in_dim(x, 0, L, axis=ax)
                leaves.append(jnp.expand_dims(x, ax))
            out["caches"] = jax.tree.unflatten(
                jax.tree.structure(self.caches), leaves)
        else:
            bs = self.block_size
            nb = -(-pos // bs)
            leaves = []
            for d, ax, sx in zip(jax.tree.leaves(payload["caches"]),
                                 self._batch_axes, self._seq_axes):
                x = jnp.squeeze(d, axis=ax)
                s = sx - 1 if ax < sx else sx
                if x.shape[s] < nb * bs:
                    pad = [(0, 0)] * x.ndim
                    pad[s] = (0, nb * bs - x.shape[s])
                    x = jnp.pad(x, pad)
                else:
                    x = jax.lax.slice_in_dim(x, 0, nb * bs, axis=s)
                x = x.reshape(x.shape[:s] + (nb, bs) + x.shape[s + 1:])
                leaves.append(x)
            out["seq"] = (list(req.prompt) + list(req.output))[:pos]
            out["n_blocks"] = nb
            out["blocks"] = jax.tree.unflatten(
                jax.tree.structure(self.caches), leaves)
        return out

    # ------------------------------------------------- cluster cache directory
    def attach_cache_directory(self, directory, replica_id: int | None = None) -> None:
        """Start publishing this replica's prefix-index deltas (insert,
        evict — migration donation and drain flow through the same two
        events) into a cluster cache directory, and push the current index
        so the directory is warm from the first lookup.  A no-op on dense
        or prefix-cache-disabled engines — they have nothing to advertise."""
        if not (self.paged and self.prefix_enabled):
            return
        rid = replica_id if replica_id is not None \
            else getattr(self, "lb_id", id(self))
        self.prefix.attach_sink(directory, rid)
        directory.reconcile(rid, self.prefix.reachable_chains())

    def detach_cache_directory(self, directory=None) -> None:
        """Stop publishing; with ``directory`` given, also invalidate every
        entry this replica claimed (scale-down: its pool is going away)."""
        if not self.paged:
            return
        if directory is not None and self.prefix.replica_id is not None:
            directory.drop_replica(self.prefix.replica_id)
        self.prefix.detach_sink()

    def reconcile_cache_directory(self, directory) -> tuple[int, int]:
        """Periodic anti-entropy: replace the directory's view of this
        replica with the chains its radix tree can actually serve.  Repairs
        orphaned-descendant drift and any lost events; cheap enough
        (O(cached blocks)) to run every few control ticks."""
        if not (self.paged and self.prefix_enabled):
            return (0, 0)
        rid = self.prefix.replica_id
        if rid is None:
            rid = getattr(self, "lb_id", id(self))
        return directory.reconcile(rid, self.prefix.reachable_chains())

    def kv_utilization(self) -> float:
        """KV memory in use as a fraction of the backend's budget: live
        blocks over the pool on the paged backend (the per-block charge the
        control plane trades in), occupied rows over capacity on dense."""
        return self.prefix.utilization() if self.paged else self.pool.utilization()

    def kv_bytes(self, rid: int) -> int:
        """Migration payload size (drives the handoff cost model), scaled by
        the request's actual sequence length: leaves with a KV sequence axis
        are charged min(pos, L) of their L slots; per-row state without one
        (SSM state / conv tails) is charged in full.  On the paged backend a
        request is charged its mapped blocks — per block, not per row."""
        row, _, _ = self._find_row(rid)
        if self.paged:
            return self.kv_per_block_bytes() * len(self._row_blocks[row])
        n = int(self.pos[row])
        leaves = jax.tree.leaves(self.caches)
        total = 0
        for pool, ax, L in zip(leaves, self._batch_axes, self._seq_lens):
            per_row = pool.nbytes // pool.shape[ax]
            if L is not None:
                per_row = per_row * min(n, L) // L
            total += per_row
        return total
