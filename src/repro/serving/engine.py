"""Continuous-batching inference engine (Orca-style iteration scheduling).

One engine = one model replica on one device slice.  Static shapes
throughout: a fixed decode batch of ``capacity`` rows over a ``RowPool``,
prefill bucketed to a few lengths, per-row sampling parameter vectors — so
the engine never recompiles as the request mix changes.

The control plane (core/) consumes the per-step telemetry this engine
emits; the same engine class serves as the *real* backend behind the
cluster simulator's cost model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.perf import BASELINE, PerfConfig
from repro.models import params as P
from repro.models.lm import make_model
from repro.serving.kv_cache import RowPool
from repro.serving.request import Request, State
from repro.serving.sampling import make_sampler
from repro.serving.scheduler import Scheduler, SchedulerConfig


def _round_bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass
class StepStats:
    t: float
    decode_s: float
    prefill_s: float
    n_prefill: int
    occupancy: int
    queue_depth: int
    tokens_out: int


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params=None, *,
                 capacity: int = 8, max_len: int = 128,
                 perf: PerfConfig = BASELINE,
                 sched: SchedulerConfig = SchedulerConfig(),
                 buckets: tuple[int, ...] = (16, 32, 64),
                 seed: int = 0):
        self.cfg = cfg
        self.perf = perf
        self.model = make_model(cfg, perf)
        self.capacity = capacity
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets))
        if params is None:
            params = P.init(jax.random.PRNGKey(seed), self.model.param_specs())
        self.params = params
        self.scheduler = Scheduler(sched)
        self.pool = RowPool(capacity)
        self.key = jax.random.PRNGKey(seed + 1)

        # device state ------------------------------------------------------
        cache_specs = self.model.cache_specs(capacity, max_len)
        self._batch_axes = [s.axes.index("batch")
                            for s in jax.tree.leaves(cache_specs, is_leaf=P.is_spec)]
        self.caches = P.init(jax.random.PRNGKey(0), cache_specs)
        self.tokens = jnp.zeros((capacity, 1), jnp.int32)
        self.pos = np.zeros((capacity,), np.int32)

        # host-side per-row bookkeeping --------------------------------------
        self.row_req: dict[int, Request] = {}
        self._temp = np.zeros((capacity,), np.float32)
        self._topk = np.zeros((capacity,), np.int32)
        self._topp = np.ones((capacity,), np.float32)

        # jitted programs -----------------------------------------------------
        self._sampler = make_sampler()
        self._decode = jax.jit(
            lambda p, t, pos, c: self.model.decode_step(p, t, pos, c),
            donate_argnums=(3,))
        self._prefill = {}  # bucket -> jit
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.history: list[StepStats] = []
        self.finished: list[Request] = []

    # ------------------------------------------------------------- internals
    def _insert_impl(self, pool_tree, new_tree, row):
        pl = jax.tree.leaves(pool_tree)
        nl = jax.tree.leaves(new_tree)
        out = []
        for pool, new, ax in zip(pl, nl, self._batch_axes):
            starts = [0] * pool.ndim
            starts[ax] = row
            out.append(jax.lax.dynamic_update_slice(
                pool, new.astype(pool.dtype), tuple(starts)))
        return jax.tree.unflatten(jax.tree.structure(pool_tree), out)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill:
            def fn(p, batch, true_len):
                logits, caches = self.model.prefill(p, batch, self.max_len,
                                                    true_len=true_len)
                return logits, caches
            self._prefill[bucket] = jax.jit(fn)
        return self._prefill[bucket]

    # ------------------------------------------------------------- interface
    def submit(self, req: Request, now: float | None = None) -> bool:
        now = time.perf_counter() if now is None else now
        return self.scheduler.submit(req, now)

    def pending(self) -> int:
        return self.scheduler.depth() + self.pool.used

    def _admit(self, req: Request, now: float) -> None:
        row = self.pool.allocate(req.rid)
        assert row is not None
        req.row, req.state, req.t_admit = row, State.PREFILL, now
        bucket = _round_bucket(len(req.prompt), self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, : len(req.prompt)] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.num_vision_tokens:
            batch["patches"] = jnp.asarray(
                req.extras.get("patches",
                               np.zeros((1, self.cfg.num_vision_tokens, self.cfg.d_model),
                                        np.float32)))
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.asarray(
                req.extras.get("frames",
                               np.zeros((1, self.cfg.encoder_seq, self.cfg.d_model),
                                        np.float32)))
        true_len = jnp.asarray([len(req.prompt)], jnp.int32)
        logits, row_caches = self._prefill_fn(bucket)(self.params, batch, true_len)
        # first token
        self.key, sk = jax.random.split(self.key)
        tok = self._sampler(logits.astype(jnp.float32), sk,
                            jnp.asarray([req.sampling.temperature], jnp.float32),
                            jnp.asarray([req.sampling.top_k], jnp.int32),
                            jnp.asarray([req.sampling.top_p], jnp.float32))
        tok_i = int(tok[0])
        req.output.append(tok_i)
        req.t_first_token = now
        req.token_times.append(now)
        req.state = State.DECODE
        # install row
        self.caches = self._insert(self.caches, row_caches, row)
        prefix = self.cfg.num_vision_tokens or 0
        self.pos[row] = len(req.prompt) + prefix
        self.tokens = self.tokens.at[row, 0].set(tok_i)
        self._temp[row] = req.sampling.temperature
        self._topk[row] = req.sampling.top_k
        self._topp[row] = req.sampling.top_p
        self.row_req[row] = req

    def _retire(self, row: int, now: float) -> None:
        req = self.row_req.pop(row)
        req.state = State.DONE
        req.t_finish = now
        req.row = None
        self.pool.free(row)
        self.finished.append(req)

    def step(self, now: float | None = None) -> StepStats:
        """One engine iteration: admit -> prefill(s) -> one decode step."""
        now = time.perf_counter() if now is None else now
        t_pre = 0.0
        incoming = self.scheduler.next_batch(self.capacity - self.pool.used, now)
        for req in incoming:
            t0 = time.perf_counter()
            self._admit(req, now)
            t_pre += time.perf_counter() - t0

        tokens_out = 0
        t_dec = 0.0
        if self.row_req:
            t0 = time.perf_counter()
            pos_dev = jnp.asarray(self.pos)
            logits, self.caches = self._decode(
                self.params, self.tokens, pos_dev, self.caches)
            self.key, sk = jax.random.split(self.key)
            sampled = self._sampler(logits.astype(jnp.float32), sk,
                                    jnp.asarray(self._temp), jnp.asarray(self._topk),
                                    jnp.asarray(self._topp))
            sampled = np.asarray(jax.device_get(sampled))
            t_dec = time.perf_counter() - t0
            new_tokens = np.asarray(self.tokens).copy()
            for row, req in list(self.row_req.items()):
                t = int(sampled[row])
                req.output.append(t)
                req.token_times.append(now)
                tokens_out += 1
                self.pos[row] += 1
                new_tokens[row, 0] = t
                stop = req.sampling.stop_token
                if (len(req.output) >= req.sampling.max_new_tokens
                        or (stop is not None and t == stop)
                        or self.pos[row] >= self.max_len - 1):
                    self._retire(row, now)
            self.tokens = jnp.asarray(new_tokens)

        st = StepStats(t=now, decode_s=t_dec, prefill_s=t_pre,
                       n_prefill=len(incoming), occupancy=self.pool.used,
                       queue_depth=self.scheduler.depth(), tokens_out=tokens_out)
        self.history.append(st)
        return st

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # --------------------------------------------------------- migration
    def extract_row(self, rid: int):
        """Remove a mid-generation request, returning its migration payload
        (request, row cache tree with batch dim 1, absolute pos, last token).
        The row is freed (Llumnix-style pause-and-copy handoff)."""
        rows = [r for r, q in self.row_req.items() if q.rid == rid]
        assert rows, f"rid {rid} not active here"
        row = rows[0]
        req = self.row_req.pop(row)
        leaves = jax.tree.leaves(self.caches)
        sliced = []
        for pool, ax in zip(leaves, self._batch_axes):
            sliced.append(jax.lax.dynamic_slice_in_dim(pool, row, 1, axis=ax))
        payload = {
            "caches": jax.tree.unflatten(jax.tree.structure(self.caches), sliced),
            "pos": int(self.pos[row]),
            "last_token": int(np.asarray(self.tokens)[row, 0]),
        }
        req.state = State.MIGRATING
        req.row = None
        req.migrations += 1
        self.pool.free(row)
        return req, payload

    def adopt(self, req: Request, payload: dict, now: float | None = None) -> bool:
        """Install a migrated request (cache shapes must match: same cfg,
        capacity-independent, same max_len)."""
        now = time.perf_counter() if now is None else now
        row = self.pool.allocate(req.rid)
        if row is None:
            return False
        self.caches = self._insert(self.caches, payload["caches"], row)
        self.pos[row] = payload["pos"]
        self.tokens = self.tokens.at[row, 0].set(payload["last_token"])
        self._temp[row] = req.sampling.temperature
        self._topk[row] = req.sampling.top_k
        self._topp[row] = req.sampling.top_p
        self.row_req[row] = req
        req.row, req.state = row, State.DECODE
        return True

    def kv_bytes(self, rid: int) -> int:
        """Migration payload size (drives the handoff cost model)."""
        leaves = jax.tree.leaves(self.caches)
        total = 0
        for pool, ax in zip(leaves, self._batch_axes):
            total += pool.nbytes // pool.shape[ax]
        return total
