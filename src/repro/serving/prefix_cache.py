"""Block-level prefix caching over a paged KV pool.

:class:`PrefixCache` owns every block of the paged KV pool and layers three
mechanisms on top of a plain free list:

* **Ref-counted sharing** — a block may back several live sequences at once
  (all of them read the same prompt-prefix KV).  A block returns to the free
  list only when its refcount reaches zero *and* it is not retained by the
  cache index.
* **Radix/trie prefix index** — full blocks form a radix tree whose edges
  are ``(parent node, the block's own tokens)``, plus one partially-filled
  *tail* block per node.  ``match`` walks edge-by-edge (each prompt token
  hashed once, O(L)) and returns the longest cached prefix of a new
  prompt; those tokens never get prefilled again.
* **LRU eviction + copy-on-write** — unreferenced cached blocks sit in an
  LRU; allocation reclaims them oldest-first, so the cache can use the whole
  idle pool without ever blocking live traffic.  Matching a partial tail
  hands a sequence a block it must not write (the cache — and possibly other
  sequences — still read it); ``needs_cow`` tells the engine to copy it into
  a private block before the first append.

The engine charges KV memory per block through this class (``used_blocks`` /
``utilization``), which is what the control plane's autoscaler and balancer
consume instead of the dense per-row worst case.

A cluster cache directory (``core/cache_directory.py``) can subscribe to
index mutations through :meth:`PrefixCache.attach_sink`: every full block
indexed or dropped is published as a content-addressed **chain hash** —
``chain_key`` folded block-by-block from the radix root — so replicas with
different local block ids and node ids still report the same key for the
same cached token prefix.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Sequence


Key = tuple[int, ...]

#: chain hash of the radix root (the empty prefix)
ROOT_CHAIN = 0


def chain_key(parent_chain: int, tokens: Key) -> int:
    """Content address of a full cached block: hash of the parent prefix's
    chain and the block's own tokens.  Replica-independent — two caches
    holding the same token prefix report the same chain — which is what
    lets a cluster directory aggregate per-replica radix trees."""
    h = hashlib.blake2b(f"{parent_chain}/{tokens!r}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def chain_walk(tokens: Sequence[int], block_size: int,
               limit: int | None = None) -> list[int]:
    """Chain hashes of every consecutive-from-root full block of ``tokens``,
    in prefix order.  ``limit`` defaults to ``len(tokens) - 1``, mirroring
    ``PrefixCache.lookup`` (the last prompt token is always recomputed for
    first-token logits).  The shared walk under directory ``announce``/
    ``overlaps`` and the transport property tests."""
    if limit is None:
        limit = len(tokens) - 1
    out: list[int] = []
    chain = ROOT_CHAIN
    n = 0
    while n + block_size <= limit:
        chain = chain_key(chain, tuple(tokens[n:n + block_size]))
        out.append(chain)
        n += block_size
    return out


@dataclasses.dataclass
class CachedBlock:
    block: int
    parent: int              # radix node the block extends (0 = root)
    tokens: Key              # tokens stored in the block (len == bs if full)
    node: int | None         # this block's radix node id; None for tails
    chain: int | None = None  # content chain hash (full blocks only)


class PrefixCache:
    """Ref-counted block allocator with a block-granularity prefix index."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: dict[int, int] = {}
        # radix index over full blocks: edges are (parent node, block tokens)
        # so a lookup hashes each token once, O(L) per walk — never the whole
        # growing prefix per step.  One partial tail may hang off any node.
        self._full: dict[tuple[int, Key], CachedBlock] = {}
        self._tail: dict[int, CachedBlock] = {}    # node -> partial tail
        self._entry: dict[int, CachedBlock] = {}   # cached block -> entry
        self._next_node = 1                        # 0 is the root
        self._lru: OrderedDict[int, None] = OrderedDict()  # ref==0 & cached
        # telemetry (token-granularity, cumulative)
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.evictions = 0
        self.cow_copies = 0
        self.inserted_blocks = 0
        # bumped whenever the index mutates; lets callers memoise lookups
        self.generation = 0
        # optional cluster-directory event sink (attach_sink): receives
        # on_insert/on_evict deltas for every full block this index retains
        self._sink = None
        self.replica_id: int | None = None

    # ------------------------------------------------------- directory sink
    def attach_sink(self, sink, replica_id: int) -> None:
        """Publish index deltas to a cluster cache directory.  ``sink``
        needs ``on_insert(replica_id, chain)`` and
        ``on_evict(replica_id, chain)``; the current index is pushed via
        :meth:`reachable_chains` + ``sink.reconcile`` by the caller."""
        self._sink = sink
        self.replica_id = replica_id

    def detach_sink(self) -> None:
        self._sink = None

    def _publish(self, event: str, chain: int | None) -> None:
        if self._sink is None or chain is None:
            return
        if event == "insert":
            self._sink.on_insert(self.replica_id, chain)
        else:
            self._sink.on_evict(self.replica_id, chain)

    def reachable_chains(self) -> set[int]:
        """Chain hashes of every full block reachable from the radix root —
        the prefixes :meth:`match` can actually serve.  Orphaned descendants
        of an evicted parent still hold pool blocks (``_entry``) but are
        excluded: a directory reconciled against this set never routes a
        prompt to an unservable prefix."""
        children: dict[int, list[CachedBlock]] = {}
        for e in self._full.values():
            children.setdefault(e.parent, []).append(e)
        out: set[int] = set()
        stack = [0]
        while stack:
            node = stack.pop()
            for e in children.get(node, ()):
                if e.chain is not None:
                    out.add(e.chain)
                stack.append(e.node)
        return out

    # ------------------------------------------------------------- refcounts
    def ref(self, block: int) -> int:
        return self._ref.get(block, 0)

    def incref(self, block: int) -> None:
        n = self._ref.get(block, 0)
        if n == 0 and block in self._lru:      # referenced again: not evictable
            del self._lru[block]
        self._ref[block] = n + 1

    def decref(self, block: int) -> None:
        n = self._ref.get(block, 0)
        if n <= 0:
            raise ValueError(f"decref of unreferenced block {block}")
        n -= 1
        self._ref[block] = n
        if n == 0:
            del self._ref[block]
            if block in self._entry:           # retained by the cache: evictable
                self._lru[block] = None
            else:
                self._free.append(block)

    # ------------------------------------------------------------ allocation
    def allocate(self, n: int = 1) -> list[int] | None:
        """n fresh blocks (refcount 1 each), evicting LRU cached blocks if the
        free list runs dry.  None if even eviction cannot cover the request —
        every block is referenced by a live sequence."""
        if len(self._free) + len(self._lru) < n:
            return None
        out = []
        for _ in range(n):
            if not self._free:
                self._evict_one()
            b = self._free.pop()
            self._ref[b] = 1
            out.append(b)
        return out

    def _evict_one(self) -> None:
        block, _ = self._lru.popitem(last=False)   # oldest first
        self._uncache(block)
        self._free.append(block)
        self.evictions += 1
        self.generation += 1

    def _uncache(self, block: int) -> None:
        e = self._entry.pop(block)
        if e.node is not None:
            if self._full.get((e.parent, e.tokens)) is e:
                del self._full[(e.parent, e.tokens)]
            # descendants keyed under e.node become unreachable; they stay
            # refcounted/LRU-tracked and age out through normal eviction —
            # the directory keeps their chains until reconciliation, which
            # is the staleness the directory contract tolerates
            self._publish("evict", e.chain)
        elif self._tail.get(e.parent) is e:
            del self._tail[e.parent]

    # ---------------------------------------------------------------- lookup
    def lookup(self, tokens: list[int]) -> int:
        """Longest cached prefix length, in tokens, without taking refs.
        Capped at len(tokens)-1: the last prompt token must always be
        prefilled to produce first-token logits."""
        return self._walk(tokens)[1]

    def _walk(self, tokens: list[int]) -> tuple[list[int], int]:
        bs = self.block_size
        limit = len(tokens) - 1
        blocks: list[int] = []
        n, node = 0, 0
        while n + bs <= limit:
            e = self._full.get((node, tuple(tokens[n : n + bs])))
            if e is None:
                break
            blocks.append(e.block)
            node = e.node
            n += bs
        t = self._tail.get(node)
        if t is not None and 0 < len(t.tokens) <= limit - n and \
                tuple(tokens[n : n + len(t.tokens)]) == t.tokens:
            blocks.append(t.block)
            n += len(t.tokens)
        return blocks, n

    def match(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached prefix of ``tokens``: (blocks, n_tokens).  Each
        returned block is increfed (the caller owns one reference) and
        touched in the LRU.  The last block may be a partial tail — the
        caller must CoW it before writing (``needs_cow``)."""
        blocks, n = self._walk(tokens)
        for b in blocks:
            # incref pulls the block out of the LRU; recency is re-stamped
            # when the final decref re-appends it
            self.incref(b)
        self.hit_tokens += n
        self.miss_tokens += max(len(tokens) - n, 0)
        return blocks, n

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: list[int], blocks: list[int], n_valid: int) -> int:
        """Index a retiring sequence's blocks under its token prefix.

        ``tokens``: the sequence's tokens whose KV is materialised (prompt +
        generated-minus-last); ``blocks``: its block table; ``n_valid``: how
        many leading tokens of ``tokens`` have KV written.  Blocks already
        indexed (same key) are skipped — dedup keeps one block per prefix.
        Returns the number of newly indexed blocks.  Does NOT change
        refcounts: the caller still holds its per-sequence references and
        releases them afterwards; cache retention is orthogonal to refs.
        """
        bs = self.block_size
        n_valid = min(n_valid, len(tokens), len(blocks) * bs)
        added = 0
        nfull = n_valid // bs
        node, chain_ok = 0, True
        chain = ROOT_CHAIN
        for i in range(nfull):
            btoks = tuple(tokens[i * bs : (i + 1) * bs])
            chain = chain_key(chain, btoks)
            e = self._full.get((node, btoks))
            if e is not None:                  # path already indexed: descend
                node = e.node
                continue
            b = blocks[i]
            if b in self._entry:               # indexed under another path —
                chain_ok = False               # deeper nodes would be orphans
                break
            e = CachedBlock(b, node, btoks, node=self._next_node, chain=chain)
            self._next_node += 1
            self._full[(node, btoks)] = e
            self._entry[b] = e
            self._publish("insert", chain)
            added += 1
            node = e.node
        # partial tail
        rem = n_valid - nfull * bs
        if chain_ok and rem > 0 and nfull < len(blocks):
            btoks = tuple(tokens[nfull * bs : n_valid])
            cur = self._tail.get(node)
            b = blocks[nfull]
            if (cur is None or len(cur.tokens) < len(btoks)) and b not in self._entry:
                if cur is not None:
                    self._drop_entry(cur.block)
                e = CachedBlock(b, node, btoks, node=None)
                self._tail[node] = e
                self._entry[b] = e
                added += 1
        self.inserted_blocks += added
        if added:
            self.generation += 1
        return added

    def _drop_entry(self, block: int) -> None:
        """Remove a block from the index; free it if unreferenced."""
        self._uncache(block)
        self.generation += 1
        if block in self._lru:
            del self._lru[block]
            self._free.append(block)

    # ------------------------------------------------------------------ misc
    def needs_cow(self, block: int) -> bool:
        """True if writing this block would corrupt another reader: it is
        shared by other sequences or retained by the cache index."""
        return self.ref(block) > 1 or block in self._entry

    def release(self, blocks: list[int]) -> None:
        for b in blocks:
            self.decref(b)

    # ------------------------------------------------------------- telemetry
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Blocks referenced by live sequences."""
        return len(self._ref)

    @property
    def cached_blocks(self) -> int:
        """Blocks retained by the prefix index (referenced or evictable)."""
        return len(self._entry)

    @property
    def evictable_blocks(self) -> int:
        return len(self._lru)

    def utilization(self) -> float:
        """Fraction of the pool holding live (referenced) blocks."""
        return self.used_blocks / max(self.num_blocks, 1)

    def hit_rate(self) -> float:
        seen = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / seen if seen else 0.0

    def adopt_blocks(self, seq: list[int], n_valid: int,
                     extra_horizon: int = 0,
                     reserved: int = 0) -> tuple[list[int], int] | None:
        """Destination-side block plan for a migrated sequence whose KV
        covers positions ``[0, n_valid)``.

        Full blocks whose token content this cache already indexes are
        *reused* (read-shared, never re-transferred); the rest are freshly
        allocated for the sender's payload to land in.  Admission is
        reservation-aware: the plan is refused — with the speculative match
        fully rolled back, so a refused adopt leaves the cache untouched —
        unless the fresh blocks *plus* ``extra_horizon`` (blocks the adopted
        request may still grow into) fit what live rows have not already
        reserved (``reserved``).  Hit/miss telemetry is neutralised: a
        migration is a transfer, not a served prompt.

        Returns ``(blocks, n_keep)`` — the full position-aligned block list
        (blocks[:n_keep] reused, blocks[n_keep:] fresh, refcount held on
        all) — or ``None`` when the pool cannot admit the request.
        """
        bs = self.block_size
        n_total = -(-n_valid // bs)
        hit_blocks: list[int] = []
        n_hit = 0
        if seq:
            hit_blocks, n_hit = self.match(seq)
            # neutralise the counters match() bumped
            self.hit_tokens -= n_hit
            self.miss_tokens -= max(len(seq) - n_hit, 0)
            if n_hit % bs:
                # only aligned full blocks can stand in for transferred
                # ones — a partial tail is dropped, not fast-forwarded
                self.decref(hit_blocks.pop())
                n_hit -= n_hit % bs
        n_keep = min(n_hit // bs, len(hit_blocks))
        del hit_blocks[n_keep:]
        fresh_needed = n_total - n_keep
        if (fresh_needed + extra_horizon
                > self.free_blocks + self.evictable_blocks - reserved):
            self.release(hit_blocks)
            return None
        fresh = self.allocate(fresh_needed) if fresh_needed else []
        if fresh is None:                      # unreachable given the check
            self.release(hit_blocks)
            return None
        return hit_blocks + fresh, n_keep

    def check_invariants(self) -> None:
        """Structural audit used by the property tests."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for b in free:
            assert self.ref(b) == 0 and b not in self._entry and b not in self._lru
        for b, n in self._ref.items():
            assert n > 0, f"non-positive refcount {n} for block {b}"
            assert b not in free and b not in self._lru
        for b in self._lru:
            assert self.ref(b) == 0 and b in self._entry
        for (pid, btoks), e in self._full.items():
            assert self._entry.get(e.block) is e
            assert e.parent == pid and e.tokens == btoks and e.node is not None
            assert e.chain is not None, "full block missing its chain hash"
        for pid, e in self._tail.items():
            assert self._entry.get(e.block) is e
            assert e.parent == pid and e.node is None and e.chain is None
        tracked = len(free) + len(self._ref) + len(self._lru)
        assert tracked == self.num_blocks, (tracked, self.num_blocks)
