"""KV-cache management for serving.

Two allocators:

* :class:`RowPool` — fixed-slot continuous-batching pool: each active request
  owns one row of the (B, L, KV, hd) per-layer cache tree.  This is what the
  CPU-engine decode path uses (static shapes, zero recompilation).

* :class:`PagedAllocator` + :class:`PagedKVCache` — PagedAttention adapted to
  TPU: KV lives in (num_blocks, block_size, KV, hd) pools indexed through
  per-sequence block tables.  Block gathers become VMEM-tiled loops in the
  Pallas kernel (kernels/paged_attention); here we keep the allocator and the
  pure-jnp ops the kernel is validated against.  Allocator telemetry
  (utilization / fragmentation) feeds the control-plane profiler.

The serving engine's paged backend allocates through
``serving/prefix_cache.PrefixCache`` instead — the ref-counted superset of
:class:`PagedAllocator` (block sharing, LRU-evictable cache retention,
copy-on-write).  :class:`PagedAllocator` stays as the minimal non-shared
control structure the kernel and property tests drive.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- rows
class RowPool:
    """Free-list of batch rows in a fixed decode batch."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._free = list(range(capacity - 1, -1, -1))
        self.owner: dict[int, int] = {}          # row -> rid

    def allocate(self, rid: int) -> int | None:
        if not self._free:
            return None
        row = self._free.pop()
        self.owner[row] = rid
        return row

    def free(self, row: int) -> None:
        assert row in self.owner, f"double free of row {row}"
        del self.owner[row]
        self._free.append(row)

    @property
    def used(self) -> int:
        return self.capacity - len(self._free)

    def utilization(self) -> float:
        return self.used / max(self.capacity, 1)


# -------------------------------------------------------------------- paged
@dataclasses.dataclass
class SeqAlloc:
    blocks: list[int]
    length: int


class PagedAllocator:
    """Host-side block allocator (the PagedAttention control structure)."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, -1, -1))
        self.seqs: dict[int, SeqAlloc] = {}

    def _need(self, length: int) -> int:
        return -(-length // self.block_size)

    def allocate(self, rid: int, length: int) -> list[int] | None:
        n = self._need(max(length, 1))
        if len(self._free) < n or rid in self.seqs:
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self.seqs[rid] = SeqAlloc(blocks, length)
        return blocks

    def extend(self, rid: int, new_length: int) -> list[int] | None:
        """Grow a sequence; returns newly added blocks (may be empty), or
        None if out of memory (caller should evict/migrate)."""
        if rid not in self.seqs:
            raise ValueError(f"extend of unknown rid {rid}: allocate() it "
                             f"first (live rids: {sorted(self.seqs)})")
        a = self.seqs[rid]
        need = self._need(new_length) - len(a.blocks)
        if need < 0:
            need = 0
        if len(self._free) < need:
            return None
        new = [self._free.pop() for _ in range(need)]
        a.blocks.extend(new)
        a.length = new_length
        return new

    def free(self, rid: int) -> None:
        a = self.seqs.pop(rid)
        self._free.extend(a.blocks)

    # ---------------------------------------------------------- telemetry
    def blocks_used(self) -> int:
        return self.num_blocks - len(self._free)

    def utilization(self) -> float:
        """Fraction of the pool holding live blocks."""
        return self.blocks_used() / max(self.num_blocks, 1)

    def internal_fragmentation(self) -> float:
        """Wasted tail-of-block slots / allocated slots."""
        alloc = sum(len(a.blocks) for a in self.seqs.values()) * self.block_size
        live = sum(a.length for a in self.seqs.values())
        return 0.0 if alloc == 0 else 1.0 - live / alloc

    def block_table(self, rid: int, max_blocks: int) -> np.ndarray:
        t = np.full((max_blocks,), -1, np.int32)
        b = self.seqs[rid].blocks[:max_blocks]
        t[: len(b)] = b
        return t


class PagedKVCache:
    """Device-side paged pools for one attention layer."""

    def __init__(self, num_blocks: int, block_size: int, kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.block_size = block_size
        shape = (num_blocks, block_size, kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)

    def write(self, block_table, pos, k_new, v_new):
        """Scatter one token per row.  block_table (B, max_blk) int32,
        pos (B,) absolute positions, k/v_new (B, KV, hd)."""
        self.k, self.v = paged_write(self.k, self.v, block_table, pos, k_new, v_new)
        return self


def paged_write(k_pool, v_pool, block_table, pos, k_new, v_new, live=None):
    """Scatter one token per row.  Rows whose table slot is -1 (no block
    mapped at ``pos``) or whose ``live`` flag is False are exact no-ops:
    their update is redirected out of bounds and dropped, never clamped
    into block 0 (which belongs to some other sequence)."""
    nb, bs = k_pool.shape[:2]
    max_blk = block_table.shape[1]
    blk_idx = jnp.clip(pos // bs, 0, max_blk - 1)
    blk = jnp.take_along_axis(block_table, blk_idx[:, None], axis=1)[:, 0]
    ok = jnp.logical_and(blk >= 0, pos // bs < max_blk)
    if live is not None:
        ok = jnp.logical_and(ok, live)
    blk = jnp.where(ok, blk, nb)                               # nb == OOB
    off = pos % bs
    k_pool = k_pool.at[blk, off].set(k_new.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[blk, off].set(v_new.astype(v_pool.dtype), mode="drop")
    return k_pool, v_pool


def paged_write_chunk(k_pool, v_pool, block_table, pos0, n_valid, k_new, v_new):
    """Append a chunk of C tokens per row at absolute positions
    pos0 .. pos0+n_valid-1 through the block table.

    k/v_new: (B, C, KV, hd) right-padded chunk projections; pos0/n_valid
    (B,) int32.  Rows with n_valid == 0 (idle pool rows riding along in the
    batched chunk program) and pad positions are dropped, not clamped."""
    nb, bs = k_pool.shape[:2]
    B, C = k_new.shape[:2]
    max_blk = block_table.shape[1]
    pos = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]     # (B,C)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    blk_idx = jnp.clip(pos // bs, 0, max_blk - 1)
    blk = jnp.take_along_axis(block_table, blk_idx, axis=1)           # (B,C)
    ok = valid & (blk >= 0) & (pos // bs < max_blk)
    blk = jnp.where(ok, blk, nb).reshape(-1)
    off = (pos % bs).reshape(-1)
    kf = k_new.reshape(B * C, *k_new.shape[2:])
    vf = v_new.reshape(B * C, *v_new.shape[2:])
    k_pool = k_pool.at[blk, off].set(kf.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[blk, off].set(vf.astype(v_pool.dtype), mode="drop")
    return k_pool, v_pool


def paged_gather(pool, block_table, max_len: int):
    """(B, max_len, KV, hd) contiguous view gathered through block tables —
    the pure-jnp oracle for the paged kernel.

    ``max_len`` need not be a multiple of the block size: the block count is
    rounded up and the ragged tail kept (a floor here silently dropped the
    last ``max_len % bs`` tokens).  Slots beyond a sequence's mapped blocks
    (table == -1) are masked to zero rather than aliasing block 0."""
    B, max_blk = block_table.shape
    bs = pool.shape[1]
    n_blk = min(-(-max_len // bs), max_blk)
    tbl = block_table[:, :n_blk]                               # (B, n_blk)
    gathered = pool[jnp.maximum(tbl, 0)]                       # (B, n_blk, bs, ...)
    mask = (tbl >= 0).reshape(B, n_blk, *([1] * (pool.ndim - 1)))
    gathered = jnp.where(mask, gathered, jnp.zeros((), pool.dtype))
    out = gathered.reshape(B, n_blk * bs, *pool.shape[2:])
    return out[:, :max_len]
