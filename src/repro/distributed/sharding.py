"""Logical-axis sharding rules → NamedSharding / PartitionSpec.

Every parameter and key activation in the model zoo is annotated with logical
axis names (see models/params.py).  A :class:`Sharder` resolves those names to
mesh axes with **per-dim divisibility fallback**: each logical name carries a
priority list of mesh-axis candidates, and the first candidate whose total
size divides the dim (and whose axes are not already taken by an earlier dim
of the same tensor) wins.  Non-divisible dims fall back to replication, so
one rule table serves all 10 architectures (14-head qwen2 silently shards
head_dim instead of heads; 8-expert mixtral shards expert-internal d_ff
instead of the expert axis; …).

ZeRO-1: optimizer moments reuse the param resolution and then additionally
place the ``data`` axis on the largest still-unsharded dim, so optimizer
state is fully partitioned across the data-parallel group.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import params as P

# Priority lists: logical axis -> tuple of candidates; each candidate is a
# tuple of mesh axes fused onto that dim.  Missing name or empty tuple =>
# replicated.  Order within a tensor is left-to-right, first-fit.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # weights
    "vocab": (("model",),),
    "mlp": (("model",),),
    "experts": (("model",),),          # qwen3 128e, jamba 16e
    "moe_mlp": (("model",),),          # mixtral fallback (8e not divisible)
    "heads": (("model",),),
    "kv_heads": (("model",),),
    # NOTE deliberately no fallback to sharding "qkv" (head_dim): contracting
    # a model-sharded head_dim turns every attention score matmul into a
    # partial-sum all-reduce at (B,H,S,S) scores shape — measured ~1e12
    # wire-bytes/device on qwen2 train_4k.  Replicating attention when the
    # head count doesn't divide the model axis is strictly cheaper.
    "qkv": (),
    "state": (),                       # SSM state dim (small)
    "groups": (),
    "experts_r": (),                   # router output dim
    "embed": (),                       # Megatron-style: d_model replicated
    "norm": (),
    "conv": (),
    "pos": (),
    "layers": (),                      # scan axis, never sharded
    # activations
    "batch": (("pod", "data"), ("data",)),
    # xent logits rows: never allowed onto "model" so the vocab dim can take
    # it (replicated unembed re-reads the whole embedding table per chunk)
    "xent_batch": (("pod", "data"), ("data",)),
    "act_seq": (),                     # optionally ("model",) via seq-parallel rules
    # decode KV-cache length: data when batch can't shard (long_500k B=1),
    # model when kv_heads couldn't take it (qwen3-moe kv=4, whisper kv=12 …
    # otherwise the 32k cache replicates over the model axis and blows HBM)
    "act_kv": (("data",), ("model",)),
}


def _seq_parallel(rules):
    r = dict(rules)
    r["act_seq"] = (("data",),)
    return r


@dataclasses.dataclass
class Sharder:
    """Resolves logical axis names to shardings on a fixed mesh.

    ``Sharder(None)`` is the no-mesh (single-device / CPU smoke) variant:
    ``shd`` is the identity and every sharding query returns None.
    """

    mesh: Mesh | None
    rules: dict[str, tuple[tuple[str, ...], ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    # ------------------------------------------------------------ resolve
    def spec_for(self, shape: tuple[int, ...], names: tuple[str | None, ...]) -> PartitionSpec:
        assert self.mesh is not None
        mesh_axes = set(self.mesh.axis_names)
        used: set[str] = set()
        parts: list[Any] = []
        for dim, name in zip(shape, names):
            pick = None
            for cand in self.rules.get(name or "", ()):
                axes = tuple(a for a in cand if a in mesh_axes)
                if not axes or any(a in used for a in axes):
                    continue
                total = int(np.prod([self.mesh.shape[a] for a in axes]))
                if total > 1 and dim % total == 0:
                    pick = axes
                    used.update(axes)
                    break
            parts.append(None if pick is None else (pick[0] if len(pick) == 1 else pick))
        while parts and parts[-1] is None:  # trailing Nones are implicit
            parts.pop()
        return PartitionSpec(*parts)

    def named(self, shape, names) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, names))

    # ------------------------------------------------------------ act hook
    def __call__(self, x, names):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(x.shape, tuple(names)))

    # ------------------------------------------------------------ trees
    def spec_shardings(self, specs):
        """ParamSpec tree -> NamedSharding tree (params, caches, opt state)."""
        if self.mesh is None:
            return None
        return P.tree_map_specs(lambda s: self.named(s.shape, s.axes), specs)

    def zero1_spec(self, s: P.ParamSpec) -> PartitionSpec:
        """Param sharding + any unused mesh axis placed on the largest
        remaining dims (ZeRO-1 optimizer-state partitioning).  Under zero3
        rules the model axis is free on weights, so moments shard 2-D
        (data via the layer stack + model) — fp32 moments at 16-way only
        were the peak-HBM driver on gemma3-27b (13.5 GiB/device)."""
        spec = self.spec_for(s.shape, s.axes)
        parts = list(spec) + [None] * (len(s.shape) - len(spec))
        used = {a for p in parts if p is not None
                for a in (p if isinstance(p, tuple) else (p,))}
        for ax in ("data", "model"):
            sz = self.mesh.shape.get(ax, 1)
            if ax in used or sz <= 1:
                continue
            order = sorted(range(len(s.shape)), key=lambda i: -s.shape[i])
            for i in order:
                if parts[i] is None and s.shape[i] % sz == 0:
                    parts[i] = ax
                    used.add(ax)
                    break
        while parts and parts[-1] is None:
            parts.pop()
        return PartitionSpec(*parts)

    def zero1_shardings(self, param_specs):
        if self.mesh is None:
            return None
        return P.tree_map_specs(
            lambda s: NamedSharding(self.mesh, self.zero1_spec(s)), param_specs)

    def batch_shardings(self, batch_specs: dict):
        """Dict of input name -> ShapeDtypeStruct; batch dim leads."""
        if self.mesh is None:
            return None

        def one(sds):
            names = ("batch",) + ("act_seq",) + (None,) * (len(sds.shape) - 2)
            return self.named(sds.shape, names[: len(sds.shape)])

        return jax.tree.map(one, batch_specs)


def opt_sharding_tree(sharder: Sharder, param_specs):
    """Shardings for the optimizer-state pytree produced by training.optimizer
    ({"mu": <params>, "nu": <params>, "step": scalar})."""
    if sharder.mesh is None:
        return None
    moments = sharder.zero1_shardings(param_specs)
    return {
        "mu": moments,
        "nu": moments,
        "step": NamedSharding(sharder.mesh, PartitionSpec()),
    }


def rules_for(partitioning: str) -> dict:
    """Named rule-table variants (PerfConfig.partitioning)."""
    rules = dict(DEFAULT_RULES)
    if partitioning == "zero3":
        # FSDP-style: weights *stored* partitioned over data on their widest
        # weight dim and all-gathered at use (GSPMD inserts the gathers);
        # batch fans out over every mesh axis so per-device compute matches
        # TP without any TP all-reduces.  NOT via the stacked "layers" axis:
        # group counts (gemma3-27b: 10) rarely divide the data axis, which
        # silently replicated all 50 GiB of params.  The vocab axis stays
        # model-sharded: a replicated unembed re-reads the whole embedding
        # table every xent chunk (measured +40 GB/device on gemma3-27b).
        for k in ("mlp", "experts", "moe_mlp", "heads", "kv_heads"):
            rules[k] = (("data",),)
        rules["batch"] = (("pod", "data", "model"), ("pod", "data"), ("data",))
    elif partitioning == "dp":
        # pure data-parallel: batch over (pod, data, model) fused; weights
        # replicated (ZeRO-1 still shards moments over data) except the
        # embedding/vocab axis (see zero3 note).  Wins for small archs where
        # TP=16 all-reduces dwarf the matmuls.
        for k in ("mlp", "experts", "moe_mlp", "heads", "kv_heads"):
            rules[k] = ()
        rules["batch"] = (("pod", "data", "model"), ("pod", "data"), ("data",))
    elif partitioning != "tp":
        raise ValueError(f"unknown partitioning {partitioning!r}")
    return rules
