"""Application profiling (paper §3 'Application profiling').

Emulates the Prometheus/Grafana pipeline: sliding-window metric store with
per-target (layer / stage / replica) latency histograms sampled on the event
clock, percentile queries, right-skew detection, and bottleneck ranking —
the input to load balancing, autoscaling and migration decisions.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict, deque


@dataclasses.dataclass
class Sample:
    t: float
    value: float


class SeriesWindow:
    """Sliding time window of float samples with percentile queries."""

    def __init__(self, window_s: float = 15.0):
        self.window_s = window_s
        self._q: deque[Sample] = deque()

    def observe(self, t: float, value: float) -> None:
        self._q.append(Sample(t, value))
        self._evict(t)

    def _evict(self, now: float) -> None:
        while self._q and self._q[0].t < now - self.window_s:
            self._q.popleft()

    def values(self, now: float | None = None) -> list[float]:
        if now is not None:
            self._evict(now)
        return [s.value for s in self._q]

    def percentile(self, p: float, now: float | None = None) -> float:
        vals = sorted(self.values(now))
        if not vals:
            return 0.0
        i = min(len(vals) - 1, max(0, math.ceil(p / 100.0 * len(vals)) - 1))
        return vals[i]

    def mean(self, now: float | None = None) -> float:
        v = self.values(now)
        return sum(v) / len(v) if v else 0.0

    def max(self, now: float | None = None) -> float:
        v = self.values(now)
        return max(v) if v else 0.0

    def count(self, now: float | None = None) -> int:
        return len(self.values(now))

    def sum(self, now: float | None = None) -> float:
        return sum(self.values(now))

    def effective_span(self, now: float | None = None) -> float:
        """Seconds the window actually covers: ``window_s`` once full, the
        observed span before that — dividing by the full window while it is
        still filling would bias every early rate low (an autoscaler seeing
        half the true arrival rate right when it matters most)."""
        if not self._q:
            return self.window_s
        t = self._q[-1].t if now is None else now
        span = min(self.window_s, t - self._q[0].t)
        # single sample / zero span: fall back to the full window rather
        # than dividing by ~0 and reporting an absurd spike
        return span if span > 0 else self.window_s

    def rate(self, now: float) -> float:
        """Samples per second over the *covered* span (<= window_s)."""
        return self.count(now) / self.effective_span(now)

    def skewness(self, now: float | None = None) -> float:
        """Right-skew indicator: (max - median) / (median - min) proxy, plus
        Fisher skewness when the window has enough mass."""
        v = sorted(self.values(now))
        if len(v) < 3:
            return 0.0
        n = len(v)
        mean = sum(v) / n
        sd = math.sqrt(sum((x - mean) ** 2 for x in v) / n) or 1e-12
        return sum((x - mean) ** 3 for x in v) / n / sd ** 3


class Profiler:
    """Per-target metric store.  Targets are free-form strings
    ('layer/27', 'stage/3/replica/0', 'engine/decode').

    With a :class:`~repro.core.metrics.MetricsRegistry` attached, the
    profiler is a *consumer* of the metrics surface rather than a parallel
    store: every ingest also lands in registry instruments labeled by
    target (``profiler_latency_seconds`` / ``profiler_util`` /
    ``profiler_tokens_total``), so the exposition carries everything the
    control loop sees while the windows keep serving percentile queries."""

    def __init__(self, window_s: float = 15.0, registry=None):
        self.window_s = window_s
        self.registry = registry
        self._m_latency = self._m_util = self._m_tokens = None
        if registry is not None:
            self._m_latency = registry.histogram(
                "profiler_latency_seconds",
                "Observed latency per profiler target", ("target",))
            self._m_util = registry.gauge(
                "profiler_util", "Last observed utilization per target",
                ("target",))
            self._m_tokens = registry.counter(
                "profiler_tokens_total", "Tokens observed per target",
                ("target",))
        self.latency: dict[str, SeriesWindow] = defaultdict(
            lambda: SeriesWindow(window_s))
        self.util: dict[str, SeriesWindow] = defaultdict(
            lambda: SeriesWindow(window_s))
        self.tokens: dict[str, SeriesWindow] = defaultdict(
            lambda: SeriesWindow(window_s))
        self.alltime_max: dict[str, float] = defaultdict(float)
        self.alltime_count: dict[str, int] = defaultdict(int)

    # ------------------------------------------------------------- ingest
    def observe_latency(self, target: str, t: float, seconds: float) -> None:
        self.latency[target].observe(t, seconds)
        self.alltime_max[target] = max(self.alltime_max[target], seconds)
        self.alltime_count[target] += 1
        if self._m_latency is not None:
            self._m_latency.observe(seconds, target=target)

    def observe_util(self, target: str, t: float, frac: float) -> None:
        self.util[target].observe(t, frac)
        if self._m_util is not None:
            self._m_util.set(frac, target=target)

    def observe_tokens(self, target: str, t: float, n: float) -> None:
        """Token-throughput counter (engine prefill/decode tokens per step;
        the autoscaler's 'work arriving' signal alongside queue depth)."""
        self.tokens[target].observe(t, float(n))
        if self._m_tokens is not None:
            self._m_tokens.inc(float(n), target=target)

    # ------------------------------------------------------------- queries
    def p(self, target: str, pct: float, now: float | None = None) -> float:
        return self.latency[target].percentile(pct, now)

    def mean_util(self, target: str, now: float | None = None) -> float:
        return self.util[target].mean(now)

    def token_rate(self, target: str, now: float | None = None) -> float:
        """Tokens per second over the covered span of the sliding window
        (the full ``window_s`` once it has filled)."""
        w = self.tokens[target]
        return w.sum(now) / w.effective_span(now)

    def bottlenecks(self, prefix: str = "", now: float | None = None,
                    metric: str = "max") -> list[tuple[str, float]]:
        """Targets ranked by descending latency metric (paper Fig. 3).
        ``metric`` is one of "max" | "alltime_max" | "p99"."""
        if metric not in ("max", "alltime_max", "p99"):
            raise ValueError(f"unknown bottleneck metric {metric!r}: "
                             "expected 'max', 'alltime_max' or 'p99'")
        rows = []
        for tgt, w in self.latency.items():
            if not tgt.startswith(prefix):
                continue
            v = self.alltime_max[tgt] if metric == "alltime_max" else \
                (w.max(now) if metric == "max" else w.percentile(99, now))
            rows.append((tgt, v))
        return sorted(rows, key=lambda r: -r[1])

    def right_skewed(self, target: str, now: float | None = None,
                     threshold: float = 1.5) -> bool:
        return self.latency[target].skewness(now) > threshold

    def hotspot_ratio(self, prefix: str = "", metric: str = "alltime_max") -> float:
        """max-latency ratio between the worst and best target (the paper's
        '230x Layer 27 vs Layer 30' statistic)."""
        rows = self.bottlenecks(prefix, metric=metric)
        rows = [r for r in rows if r[1] > 0]
        if len(rows) < 2:
            return 1.0
        return rows[0][1] / rows[-1][1]
