"""Proactive, goodput-driven scaling policy (paper §3 'Accurate load
prediction' closed into the autoscaler loop).

The reactive HPA law scales on the *current* value of one raw metric.
This policy instead plans replica counts from three signals sampled on
the logical step clock:

1. **Forecast load** — per-endpoint arrival work (prompt + decode-budget
   tokens per step) feeds a :mod:`repro.core.predictor` forecaster, and
   the plan is made at the forecast horizon, not at "now".  The horizon
   defaults to the replica warm-up lag (``cold_start_steps``) plus one
   control period: a scale-up fired on the forecast is *schedulable* the
   moment the predicted load actually lands, hiding the cold start.
2. **Capacity model** — tokens/step one warm replica sustains, learned
   online from the served-token telemetry the profiler window already
   carries (an EWMA updated only while the endpoint is backlogged, so
   idle ticks never erode it).  ``desired = ceil(demand / capacity)``
   replaces the HPA's relative ``ceil(current * metric / target)`` —
   the policy can jump straight to the replica count the spike needs
   instead of ratcheting up one control period at a time.
3. **Goodput objective** — the fraction of SLO-carrying requests meeting
   their TTFT/TPOT deadlines, with misses decomposed by
   :func:`repro.core.tracing.attribute_slo_misses`.  Queue-dominated
   misses are a capacity shortfall: they bias the plan up beyond the
   forecast.  Scale-down is only permitted while windowed goodput holds
   at/above ``goodput_floor`` with no recent queue-dominated miss — the
   policy optimizes % of requests served within SLO, not raw utilization.

The policy plugs into :class:`repro.core.autoscaler.Autoscaler` as an
alternative desired-replica source; the HPA *behaviors* (tolerance-free
clamping, scale-down stabilization window, per-direction cooldowns) stay
shared, so proactive and reactive differ only in how "desired" is
computed, never in flap protection.

Host-side Python only (no jax): importable by the control plane and the
benchmarks alike.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.core.predictor import make_predictor


@dataclasses.dataclass
class ScalingSignals:
    """One control tick's view of an endpoint, on the logical step clock.

    Token units throughout: a request's *work* is
    ``len(prompt) + sampling.max_new_tokens`` — what admission will cost
    end to end, the same unit the capacity model learns in."""
    queue_depth: int = 0        # requests waiting cluster-wide
    queue_tokens: int = 0       # work tokens those waiting requests carry
    served_tokens: int = 0      # tokens produced since the previous tick
    steps: int = 1              # logical steps since the previous tick
    warm_replicas: int = 0      # replicas past their cold start
    total_replicas: int = 0     # including still-warming ones


@dataclasses.dataclass
class ProactiveConfig:
    """Knobs of the proactive goodput policy (defaults favor hiding a
    cold start over hugging the utilization optimum)."""
    predictor: str = "holt"             # "ewma" | "holt" | "ar"
    predictor_kw: dict = dataclasses.field(default_factory=dict)
    # forecast horizon in logical steps.  None derives the warm-up-aware
    # default: cold_start_steps + one control period — scale now, be warm
    # when the forecast load lands.
    horizon_steps: int | None = None
    # capacity model: learned tokens/step per warm replica
    capacity_floor: float = 4.0         # never plan below this throughput
    capacity_decay: float = 0.25        # EWMA weight of a fresh observation
    target_util: float = 0.8            # plan at this fraction of capacity
    # token backlog is amortized over this many steps on top of forecast
    # arrivals (a small number drains spikes aggressively)
    drain_steps: float = 8.0
    # goodput objective
    goodput_window: int = 64            # finished SLO-carrying requests
    goodput_floor: float = 0.97         # scale-down allowed at/above this
    queue_miss_boost: int = 1           # extra replicas while queue misses persist
    miss_patience: int = 2              # control ticks a miss bias survives


class ProactiveScalingPolicy:
    """Desired-replica source for :class:`~repro.core.autoscaler.Autoscaler`.

    The orchestrator feeds it arrivals (:meth:`note_arrival`) and request
    outcomes (:meth:`observe_outcomes`) and hands it a
    :class:`ScalingSignals` snapshot each control tick; the autoscaler
    asks :meth:`desired_replicas` and applies the shared HPA behaviors to
    the answer."""

    def __init__(self, cfg: ProactiveConfig | None = None, *,
                 cold_start_steps: int = 0, control_every_steps: int = 1,
                 name: str = "default"):
        self.cfg = cfg if cfg is not None else ProactiveConfig()
        self.name = name
        self.control_every = max(1, control_every_steps)
        self.horizon_steps = (self.cfg.horizon_steps
                              if self.cfg.horizon_steps is not None
                              else cold_start_steps + self.control_every)
        kw = dict(self.cfg.predictor_kw)
        if self.cfg.predictor in ("holt", "ar"):
            # observations arrive once per control tick; dt converts the
            # per-tick trend/steps into the per-step horizon contract
            kw.setdefault("dt", float(self.control_every))
        self.predictor = make_predictor(self.cfg.predictor, **kw)
        self.forecast = 0.0                 # last horizon forecast (tokens/step)
        self.forecast_error = 0.0           # |forecast - realized| at horizon
        self.capacity: float | None = None  # learned tokens/step per replica
        self._arrived_tokens = 0.0
        self._pending_forecasts: deque[tuple[float, float]] = deque()
        self._outcomes: deque[bool] = deque(maxlen=self.cfg.goodput_window)
        self._miss_bias_ticks = 0
        self._m_forecast = None

    # -------------------------------------------------------------- metrics
    def attach_metrics(self, registry, endpoint: str = "default") -> None:
        self._ep = endpoint or "default"
        self._m_forecast = registry.gauge(
            "autoscaler_forecast",
            "Forecast load at the scaling horizon (work tokens/step)",
            ("endpoint",))
        self._m_fc_err = registry.gauge(
            "autoscaler_forecast_error",
            "Abs error of the forecast made one horizon ago vs realized load",
            ("endpoint",))
        self._m_lead = registry.gauge(
            "autoscaler_lead_steps",
            "Forecast horizon in logical steps (planned scale-up lead)",
            ("endpoint",))
        self._m_goodput = registry.gauge(
            "autoscaler_goodput",
            "Windowed fraction of SLO-carrying requests meeting their SLOs",
            ("endpoint",))
        self._m_capacity = registry.gauge(
            "autoscaler_capacity_tokens_per_step",
            "Learned per-replica serving capacity (work tokens/step)",
            ("endpoint",))
        self._m_lead.set(self.horizon_steps, endpoint=self._ep)

    # --------------------------------------------------------------- inputs
    def note_arrival(self, now: float, work_tokens: float) -> None:
        """One submitted request's work (prompt + decode budget tokens)."""
        self._arrived_tokens += float(work_tokens)

    def observe_outcomes(self, finished, miss_rows) -> None:
        """Score requests that finished since the last tick against their
        SLOs, and ingest their :func:`attribute_slo_misses` rows — a
        queue-dominated miss arms the scale-up bias for
        ``miss_patience`` control ticks."""
        for r in finished:
            if r.slo_ttft is not None or r.slo_tpot is not None:
                self._outcomes.append(bool(r.slo_met()))
        if any(row.get("dominant") == "queue_wait" for row in miss_rows):
            self._miss_bias_ticks = self.cfg.miss_patience

    def goodput(self) -> float:
        """Windowed goodput; an empty window reads as healthy (1.0)."""
        if not self._outcomes:
            return 1.0
        return sum(self._outcomes) / len(self._outcomes)

    # ---------------------------------------------------------- control tick
    def on_control_tick(self, t: float, sig: ScalingSignals) -> None:
        """Sample the arrival window, update the capacity model, advance
        the forecaster, and refresh the gauges.  Called exactly once per
        control tick, before :meth:`desired_replicas`."""
        steps = max(sig.steps, 1)
        rate = self._arrived_tokens / steps
        self._arrived_tokens = 0.0
        # capacity: tokens/step per warm replica, learned only while there
        # is a backlog (an idle replica serves 0 tokens/step but can do far
        # better — averaging idle ticks in would collapse the model)
        if sig.queue_depth > 0 and sig.warm_replicas > 0 \
                and sig.served_tokens > 0:
            obs = sig.served_tokens / steps / sig.warm_replicas
            d = self.cfg.capacity_decay
            self.capacity = obs if self.capacity is None else \
                (1 - d) * self.capacity + d * obs
        # realized forecast error: compare the forecast whose target time
        # has now arrived against the rate just observed
        while self._pending_forecasts and self._pending_forecasts[0][0] <= t:
            _, fc = self._pending_forecasts.popleft()
            self.forecast_error = abs(fc - rate)
        self.predictor.observe(t, rate)
        self.forecast = self.predictor.forecast(float(self.horizon_steps))
        self._pending_forecasts.append((t + self.horizon_steps, self.forecast))
        if self._m_forecast is not None:
            self._m_forecast.set(self.forecast, endpoint=self._ep)
            self._m_fc_err.set(self.forecast_error, endpoint=self._ep)
            self._m_goodput.set(self.goodput(), endpoint=self._ep)
            self._m_capacity.set(self.capacity or 0.0, endpoint=self._ep)

    # --------------------------------------------------------------- output
    def effective_capacity(self) -> float:
        cap = self.capacity if self.capacity is not None \
            else self.cfg.capacity_floor
        return max(cap, self.cfg.capacity_floor) * self.cfg.target_util

    def desired_replicas(self, t: float, current: int,
                         sig: ScalingSignals) -> int:
        """Raw desired count (the autoscaler clamps and stabilizes it):
        forecast arrivals plus amortized backlog over learned capacity,
        biased up while queue-dominated SLO misses persist, and held at
        ``current`` when goodput says scaling down would be reckless."""
        cfg = self.cfg
        demand = self.forecast + sig.queue_tokens / max(cfg.drain_steps, 1.0)
        want = math.ceil(demand / self.effective_capacity()) if demand > 0 else 1
        want = max(want, 1)     # the HPA law floors at 1; scale-to-zero is
        #                         registry policy, never a scaler decision
        biased = self._miss_bias_ticks > 0
        if biased:
            # queue-dominated misses = the plan was short; add headroom
            # beyond whichever of forecast/current is larger
            want = max(want, current + cfg.queue_miss_boost)
            # the bias is consumed here (once per control tick — the
            # autoscaler calls desired_replicas exactly once per tick), so
            # it survives exactly miss_patience plans
            self._miss_bias_ticks -= 1
        if want < current and not (self.goodput() >= cfg.goodput_floor
                                   and not biased):
            # goodput guard: only surrender replicas while the SLOs hold
            want = current
        return want
