"""Load balancing (paper §3 'Load balancing').

Istio-style request routing over the replicas of one (micro)service.
Policies: round-robin, least-outstanding-requests, power-of-two-choices,
weighted join-shortest-queue (weights = replica capacity, e.g. heterogeneous
hardware).
"""
from __future__ import annotations

import random
from typing import Callable, Sequence


class LoadBalancer:
    def __init__(self, policy: str = "p2c", seed: int = 0):
        assert policy in ("rr", "least", "p2c", "wjsq")
        self.policy = policy
        self._rr = 0
        self._rng = random.Random(seed)

    def pick(self, replicas: Sequence, load: Callable[[object], float],
             weight: Callable[[object], float] = lambda r: 1.0) -> object:
        """Choose a replica.  ``load(r)`` = outstanding work (queue depth or
        busy seconds); ``weight(r)`` = capacity multiplier."""
        live = [r for r in replicas]
        assert live, "no replicas"
        if len(live) == 1:
            return live[0]
        if self.policy == "rr":
            self._rr = (self._rr + 1) % len(live)
            return live[self._rr]
        if self.policy == "least":
            return min(live, key=load)
        if self.policy == "p2c":
            a, b = self._rng.sample(live, 2)
            return a if load(a) <= load(b) else b
        # weighted JSQ: smallest load normalised by capacity
        return min(live, key=lambda r: load(r) / max(weight(r), 1e-9))
