"""Load balancing (paper §3 'Load balancing').

Istio-style request routing over the replicas of one (micro)service.
Policies: round-robin, least-outstanding-requests, power-of-two-choices,
weighted join-shortest-queue (weights = replica capacity, e.g. heterogeneous
hardware), and prefix-affinity routing ("prefix"): requests sharing a prompt
prefix rendezvous-hash to the same replica so its paged-KV prefix cache
keeps serving them, with a load guard that spills to the least-loaded
replica when the affine one is hot — locality must never create a hotspot.
"""
from __future__ import annotations

import hashlib
import random
from typing import Callable, Hashable, Sequence


def _rendezvous(key: Hashable, idx: int) -> int:
    h = hashlib.blake2b(f"{key!r}/{idx}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


class LoadBalancer:
    def __init__(self, policy: str = "p2c", seed: int = 0,
                 affinity_slack: float = 4.0):
        assert policy in ("rr", "least", "p2c", "wjsq", "prefix")
        self.policy = policy
        self._rr = 0
        self._rng = random.Random(seed)
        # "prefix": max load gap over the coolest replica before affinity
        # yields to load balancing
        self.affinity_slack = affinity_slack

    def pick(self, replicas: Sequence, load: Callable[[object], float],
             weight: Callable[[object], float] = lambda r: 1.0,
             affinity_key: Hashable | None = None) -> object:
        """Choose a replica.  ``load(r)`` = outstanding work (queue depth or
        busy seconds); ``weight(r)`` = capacity multiplier; ``affinity_key``
        = routing key for the "prefix" policy (e.g. the prompt's first KV
        block of tokens)."""
        live = [r for r in replicas]
        assert live, "no replicas"
        if len(live) == 1:
            return live[0]
        if self.policy == "rr":
            # post-increment: replica 0 gets the first pick and the rotation
            # stays unbiased when the replica count changes
            i = self._rr % len(live)
            self._rr += 1
            return live[i]
        if self.policy == "least":
            return min(live, key=load)
        if self.policy == "p2c":
            a, b = self._rng.sample(live, 2)
            return a if load(a) <= load(b) else b
        if self.policy == "prefix":
            if affinity_key is None:
                return min(live, key=load)
            lo = min(load(r) for r in live)
            # rendezvous-hash on a stable replica identity (not the list
            # position): membership churn then remaps only the keys that
            # hashed to the departed replica, keeping warm caches warm
            ranked = sorted(live, key=lambda r: _rendezvous(
                affinity_key, getattr(r, "lb_id", id(r))), reverse=True)
            # always terminates: the minimum-load replica passes the guard
            return next(r for r in ranked
                        if load(r) <= lo + self.affinity_slack)
        # weighted JSQ: smallest load normalised by capacity
        return min(live, key=lambda r: load(r) / max(weight(r), 1e-9))
