"""Load balancing (paper §3 'Load balancing').

Istio-style request routing over the replicas of one (micro)service.
Policies: round-robin, least-outstanding-requests, power-of-two-choices,
weighted join-shortest-queue (weights = replica capacity, e.g. heterogeneous
hardware), prefix-affinity routing ("prefix": requests sharing a prompt
prefix rendezvous-hash to the same replica so its paged-KV prefix cache
keeps serving them), and cluster-directory routing ("directory": replicas
are scored by the *actual* cached-token overlap the cluster cache directory
reports for the whole prompt — beyond the first block — blended with load
slack).  Both locality policies carry a load guard: locality must never
create a hotspot.
"""
from __future__ import annotations

import hashlib
import random
from typing import Callable, Hashable, Sequence


def _rendezvous(key: Hashable, idx: int) -> int:
    h = hashlib.blake2b(f"{key!r}/{idx}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


class LoadBalancer:
    def __init__(self, policy: str = "p2c", seed: int = 0,
                 affinity_slack: float = 4.0,
                 directory=None, directory_load_weight: float = 4.0):
        assert policy in ("rr", "least", "p2c", "wjsq", "prefix", "directory")
        self.policy = policy
        self._rr = 0
        self._rng = random.Random(seed)
        # "prefix": max load gap over the coolest replica before affinity
        # yields to load balancing
        self.affinity_slack = affinity_slack
        # "directory": the ClusterCacheDirectory scored against, and how
        # many cached prompt tokens one unit of load is worth — the blend
        # that keeps cache-chasing from piling requests on one replica
        self.directory = directory
        self.directory_load_weight = directory_load_weight
        self._m_picks = None

    def attach_metrics(self, registry) -> None:
        """Bind routing instruments onto a cluster metrics registry."""
        self._m_picks = registry.counter(
            "lb_routing_decisions_total", "Routing decisions, by policy",
            ("policy",))

    def pick(self, replicas: Sequence, load: Callable[[object], float],
             weight: Callable[[object], float] = lambda r: 1.0,
             affinity_key: Hashable | None = None,
             tokens: Sequence[int] | None = None,
             block_size: int = 16) -> object:
        """Choose a replica.  ``load(r)`` = outstanding work (queue depth or
        busy seconds); ``weight(r)`` = capacity multiplier; ``affinity_key``
        = routing key for the "prefix" policy (e.g. the prompt's first KV
        block of tokens); ``tokens``/``block_size`` = the whole prompt for
        the "directory" policy's cluster-radix overlap walk."""
        live = [r for r in replicas]
        assert live, "no replicas"
        if self._m_picks is not None:
            self._m_picks.inc(policy=self.policy)
        if len(live) == 1:
            return live[0]
        if self.policy == "rr":
            # post-increment: replica 0 gets the first pick and the rotation
            # stays unbiased when the replica count changes
            i = self._rr % len(live)
            self._rr += 1
            return live[i]
        if self.policy == "least":
            return min(live, key=load)
        if self.policy == "p2c":
            a, b = self._rng.sample(live, 2)
            return a if load(a) <= load(b) else b
        if self.policy == "prefix":
            if affinity_key is None:
                return min(live, key=load)
            lo = min(load(r) for r in live)
            # rendezvous-hash on a stable replica identity (not the list
            # position): membership churn then remaps only the keys that
            # hashed to the departed replica, keeping warm caches warm
            ranked = sorted(live, key=lambda r: _rendezvous(
                affinity_key, getattr(r, "lb_id", id(r))), reverse=True)
            # always terminates: the minimum-load replica passes the guard
            return next(r for r in ranked
                        if load(r) <= lo + self.affinity_slack)
        if self.policy == "directory":
            if self.directory is None or tokens is None:
                return min(live, key=load)
            ov = self.directory.overlaps(tokens, block_size)
            lo = min(load(r) for r in live)
            # expected cached tokens minus the load premium over the coolest
            # replica: a replica must bring directory_load_weight extra
            # cached tokens per unit of extra load to justify the pick.
            # Cold directory / no overlap degrades to least-loaded exactly.
            def score(r):
                o = ov.get(getattr(r, "lb_id", id(r)), 0)
                return o - self.directory_load_weight * (load(r) - lo)
            best = max(live, key=lambda r: (score(r), -load(r)))
            return best
        # weighted JSQ: smallest load normalised by capacity
        return min(live, key=lambda r: load(r) / max(weight(r), 1e-9))
