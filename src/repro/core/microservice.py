"""Fine-grained modularization (paper §3): the model as stage microservices.

A :class:`StagedLM` splits a decoder-only LM into ``num_stages`` contiguous
group-ranges.  Each stage is an independently jitted program over its own
parameter/cache slice — the schedulable, scalable, observable unit the paper
argues for.  On TPU a stage replica is one pjit program on its own device
slice; the per-layer gRPC hop of the paper's K8s prototype becomes a
host-side handoff (see DESIGN.md §2 on why we do not emulate per-layer RPC
inside the chip domain).

:class:`StagePipeline` executes decode steps stage-by-stage with per-stage
replica sets, wall-clock profiling per stage, and batch-splitting across
replicas — the real-engine backend for the control plane.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.profiler import Profiler
from repro.models import layers as L
from repro.models.lm import LM


def _tree_slice(tree, g0: int, g1: int):
    return jax.tree.map(lambda a: a[g0:g1], tree)


def _slice_rows(stage_cache: dict, s0: int, s1: int) -> dict:
    """Batch-row slice of a stage cache ('blocks' carry batch at axis 1
    behind the stacked group axis; 'tail' entries at axis 0)."""
    out = {"blocks": jax.tree.map(lambda a: a[:, s0:s1], stage_cache["blocks"])}
    if "tail" in stage_cache:
        out["tail"] = jax.tree.map(lambda a: a[s0:s1], stage_cache["tail"])
    return out


def _concat_rows(stage_caches: list[dict]) -> dict:
    out = {"blocks": jax.tree.map(lambda *ys: jnp.concatenate(ys, axis=1),
                                  *[c["blocks"] for c in stage_caches])}
    if "tail" in stage_caches[0]:
        out["tail"] = jax.tree.map(lambda *ys: jnp.concatenate(ys, axis=0),
                                   *[c["tail"] for c in stage_caches])
    return out


class StagedLM:
    def __init__(self, model: LM, num_stages: int):
        assert not model.cfg.is_encoder_decoder, "stage split is decoder-only"
        self.model = model
        g = model.groups
        num_stages = min(num_stages, g)
        base, rem = divmod(g, num_stages)
        bounds, s = [], 0
        for i in range(num_stages):
            e = s + base + (1 if i < rem else 0)
            bounds.append((s, e))
            s = e
        self.bounds = bounds                  # group ranges per stage
        self.num_stages = num_stages
        self._stage_fns: dict[int, Any] = {}

    # ------------------------------------------------------------- slicing
    def stage_params(self, params, si: int) -> dict:
        g0, g1 = self.bounds[si]
        sp = {"blocks": _tree_slice(params["blocks"], g0, g1)}
        if si == self.num_stages - 1 and "tail" in params:
            sp["tail"] = params["tail"]
        return sp

    def stage_caches(self, caches, si: int) -> dict:
        g0, g1 = self.bounds[si]
        sc = {"blocks": _tree_slice(caches["blocks"], g0, g1)}
        if si == self.num_stages - 1 and "tail" in caches:
            sc["tail"] = caches["tail"]
        return sc

    def merge_caches(self, stage_caches: list[dict]) -> dict:
        blocks = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *[c["blocks"] for c in stage_caches])
        out = {"blocks": blocks}
        if "tail" in stage_caches[-1]:
            out["tail"] = stage_caches[-1]["tail"]
        return out

    # ------------------------------------------------------------- programs
    def embed_fn(self):
        model = self.model

        def f(params_embed, tokens):
            return L.embed_apply(params_embed, tokens, model.cfg)

        return jax.jit(f)

    def head_fn(self):
        model = self.model

        def f(params, x):
            x = L.rmsnorm(params["final_norm"], x, model.cfg.norm_eps)
            return L.unembed_logits(params["embed"], x, model.cfg)[:, 0]

        return jax.jit(f)

    def stage_fn(self, si: int):
        """jitted decode step for stage si: (stage_params, x, pos, caches) ->
        (x, new_caches)."""
        if si in self._stage_fns:
            return self._stage_fns[si]
        model = self.model
        last = si == self.num_stages - 1

        def f(sp, x, pos, sc):
            positions = pos[:, None]

            def body(carry, xs):
                x = carry
                gparams, gcache = xs
                new_entries = {}
                for j in range(model.period):
                    x, nc, _ = model._block(
                        gparams[f"m{j}"], x, model.kinds[j], model.moes[j],
                        mode="decode", positions=positions,
                        cache=gcache[f"m{j}"], pos=pos, prefix_len=0,
                        max_len=0, shd=L._noop_shd)
                    new_entries[f"m{j}"] = nc
                return x, new_entries

            x, blocks = jax.lax.scan(body, x, (sp["blocks"], sc["blocks"]))
            out = {"blocks": blocks}
            if last and "tail" in sp:
                tail = {}
                for i in model.tail_layers:
                    x, nc, _ = model._block(
                        sp["tail"][f"t{i}"], x, model.cfg.layer_kind(i),
                        model.cfg.layer_is_moe(i), mode="decode",
                        positions=positions, cache=sc["tail"][f"t{i}"],
                        pos=pos, prefix_len=0, max_len=0, shd=L._noop_shd)
                    tail[f"t{i}"] = nc
                out["tail"] = tail
            return x, out

        self._stage_fns[si] = jax.jit(f, donate_argnums=(3,))
        return self._stage_fns[si]


# --------------------------------------------------------------------- pipe
@dataclasses.dataclass
class StageReplica:
    sid: int
    idx: int
    params: Any              # stage param slice (shared arrays)
    ready_at: float = 0.0


class StagePipeline:
    """Decode executor with per-stage replica sets + profiling.

    Batch rows are split across a stage's ready replicas (the paper's
    horizontal-scaling mechanism); per-stage wall latency feeds the profiler
    under 'stage/<i>'.
    """

    def __init__(self, model: LM, params, num_stages: int,
                 profiler: Profiler | None = None):
        self.staged = StagedLM(model, num_stages)
        self.params = params
        self.profiler = profiler or Profiler()
        self.replicas: list[list[StageReplica]] = [
            [StageReplica(s, 0, self.staged.stage_params(params, s))]
            for s in range(self.staged.num_stages)]
        self._embed = self.staged.embed_fn()
        self._head = self.staged.head_fn()

    def scale_stage(self, sid: int, n: int, now: float, cold_start_s: float = 0.0):
        cur = self.replicas[sid]
        while len(cur) < n:
            cur.append(StageReplica(sid, len(cur),
                                    self.staged.stage_params(self.params, sid),
                                    ready_at=now + cold_start_s))
        del cur[n:]

    def decode_step(self, tokens, pos, caches, now: float | None = None):
        """tokens (B,1), pos (B,), full cache tree -> (logits, new caches)."""
        now = time.perf_counter() if now is None else now
        x = self._embed(self.params["embed"], tokens)
        new_stage_caches = []
        for si in range(self.staged.num_stages):
            sc = self.staged.stage_caches(caches, si)
            ready = [r for r in self.replicas[si] if r.ready_at <= now]
            ready = ready or self.replicas[si][:1]
            fn = self.staged.stage_fn(si)
            t0 = time.perf_counter()
            if len(ready) == 1:
                x, nc = fn(ready[0].params, x, pos, sc)
            else:
                # split rows across replicas; each runs the same program on
                # its shard (on real hardware these run concurrently)
                B = x.shape[0]
                per = -(-B // len(ready))
                outs, ncs = [], []
                for k, r in enumerate(ready):
                    s0, s1 = k * per, min((k + 1) * per, B)
                    if s0 >= s1:
                        break
                    xs, nck = fn(r.params, x[s0:s1], pos[s0:s1],
                                 _slice_rows(sc, s0, s1))
                    outs.append(xs)
                    ncs.append(nck)
                x = jnp.concatenate(outs, axis=0)
                nc = _concat_rows(ncs)
            dt = time.perf_counter() - t0
            self.profiler.observe_latency(f"stage/{si}", now, dt)
            new_stage_caches.append(nc)
        logits = self._head(self.params, x)
        return logits, self.staged.merge_caches(new_stage_caches)
