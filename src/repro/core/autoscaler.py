"""Cloud-native autoscaling (paper §3 'Autoscaling').

Implements the Kubernetes HPA control law exactly:

    desired = ceil(current * metric / target)

with the HPA behaviors that matter in practice: tolerance band, min/max
replicas, scale-down stabilization window (use the *max* desired over the
window to avoid flapping), per-direction cooldowns, and pod cold-start
latency (handled by the cluster layer: a new replica becomes schedulable
only after its model shard loads).

Three modes:
* reactive  — metric is the current windowed observation (paper setting)
* proactive — metric is a predictor forecast at the cold-start horizon
* policy    — a pluggable desired-replica source (e.g.
  :class:`~repro.core.scaling_policy.ProactiveScalingPolicy`, the
  goodput-driven planner) computes the raw desired count from
  :class:`~repro.core.scaling_policy.ScalingSignals`; the HPA behaviors
  (clamping, stabilization window, cooldowns) still apply to its output,
  so flap protection is identical across modes.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class HPAConfig:
    metric: str = "latency"         # 'latency' | 'util' | 'queue' | 'kv_util'
    target: float = 1.0             # target metric value (e.g. seconds / util frac)
    min_replicas: int = 1
    max_replicas: int = 8
    tolerance: float = 0.1          # +-10% dead band (K8s default)
    stabilization_s: float = 15.0   # scale-down window (paper: 15s metric window)
    scale_up_cooldown_s: float = 0.0
    scale_down_cooldown_s: float = 15.0
    proactive: bool = False
    horizon_s: float = 10.0         # forecast horizon ~ cold-start time


class Autoscaler:
    def __init__(self, cfg: HPAConfig, predictor=None, policy=None):
        self.cfg = cfg
        self.predictor = predictor
        # pluggable desired-replica source (duck type: on_control_tick(t,
        # signals), desired_replicas(t, current, signals), .forecast).
        # Engaged only when evaluate() receives a signals snapshot.
        self.policy = policy
        self._desired_hist: list[tuple[float, int]] = []
        self._last_up = -1e30
        self._last_down = -1e30
        self.decisions: list[tuple[float, int, int, float]] = []  # (t, cur, new, metric)
        self._m_events = None

    def attach_metrics(self, registry, endpoint: str = "default") -> None:
        """Bind autoscaler instruments onto a cluster metrics registry.

        ``endpoint`` labels every sample so several endpoints' autoscalers
        can share one registry without clobbering each other (label hygiene:
        callers pass a non-empty name; the bare orchestrator passes
        "default")."""
        self._ep = endpoint or "default"
        self._m_events = registry.counter(
            "autoscaler_scale_events_total", "Scale decisions, by direction",
            ("direction", "endpoint"))
        self._m_metric = registry.gauge(
            "autoscaler_metric", "Last metric value the control law saw",
            ("endpoint",))

    def _raw_desired(self, current: int, metric: float) -> int:
        c = self.cfg
        if c.target <= 0:
            return current
        ratio = metric / c.target
        if abs(ratio - 1.0) <= c.tolerance:
            return current
        return max(1, math.ceil(current * ratio))

    def evaluate(self, t: float, current: int, metric: float,
                 signals=None) -> int:
        """Returns the new replica count (== current when no action).

        With a policy attached and a ``signals`` snapshot provided, the
        raw desired count comes from the policy instead of the HPA ratio
        law; everything after (clamp, stabilization, cooldowns, decision
        log, metrics) is shared."""
        c = self.cfg
        if self.policy is not None and signals is not None:
            self.policy.on_control_tick(t, signals)
            desired = self.policy.desired_replicas(t, current, signals)
            metric = self.policy.forecast    # what the decision log records
        else:
            if c.proactive and self.predictor is not None:
                self.predictor.observe(t, metric)
                metric = self.predictor.forecast(c.horizon_s)
            desired = self._raw_desired(current, metric)
        if self._m_events is not None:
            self._m_metric.set(metric, endpoint=self._ep)
        desired = min(max(desired, c.min_replicas), c.max_replicas)

        self._desired_hist.append((t, desired))
        self._desired_hist = [(tt, d) for tt, d in self._desired_hist
                              if tt >= t - c.stabilization_s]

        if desired > current:
            if t - self._last_up < c.scale_up_cooldown_s:
                return current
            self._last_up = t
            self.decisions.append((t, current, desired, metric))
            if self._m_events is not None:
                self._m_events.inc(direction="up", endpoint=self._ep)
            return desired
        if desired < current:
            # scale-down stabilization: act on the max desired in the window;
            # cooldown counts from the last scale event in EITHER direction
            # (K8s semantics: a fresh scale-up blocks immediate down-flap)
            stab = max(d for _, d in self._desired_hist)
            stab = min(max(stab, c.min_replicas), c.max_replicas)
            last_event = max(self._last_down, self._last_up)
            if stab >= current or t - last_event < c.scale_down_cooldown_s:
                return current
            self._last_down = t
            self.decisions.append((t, current, stab, metric))
            if self._m_events is not None:
                self._m_events.inc(direction="down", endpoint=self._ep)
            return stab
        return current
