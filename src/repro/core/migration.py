"""Transparent request migration (paper §3 'Migration technology').

Llumnix/DistServe-inspired: live requests move between replicas to
(a) rebalance load, (b) drain stragglers/failing nodes, (c) defragment KV
capacity.  The decision layer is shared by the simulator and the real
engines; the handoff itself is InferenceEngine.extract_row/adopt with a
transfer-time cost model:

    t_handoff = kv_bytes / bw + overhead

bw = NVLink-class intra-node (the paper's testbed) or ICI/DCN on TPU pods.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.serving.engine import InferenceEngine
from repro.serving.request import Request


@dataclasses.dataclass
class MigrationConfig:
    imbalance_threshold: float = 0.35   # (max-min)/capacity occupancy gap
    straggler_speed: float = 0.5        # below this, drain the replica
    bandwidth_Bps: float = 200e9        # NVLink-ish; TPU ICI ~50e9/link
    overhead_s: float = 0.010
    max_concurrent: int = 2


@dataclasses.dataclass
class MigrationEvent:
    t: float
    rid: int
    src: int
    dst: int
    bytes: int
    duration_s: float


class MigrationManager:
    def __init__(self, cfg: MigrationConfig = MigrationConfig()):
        self.cfg = cfg
        self.events: list[MigrationEvent] = []

    # ------------------------------------------------------------ decision
    def plan(self, occupancies: Sequence[float],
             speeds: Sequence[float] | None = None) -> list[tuple[int, int]]:
        """Return (src_replica, dst_replica) moves given per-replica
        occupancy fractions (and optional speed factors for stragglers)."""
        n = len(occupancies)
        if n < 2:
            return []
        moves: list[tuple[int, int]] = []
        occ = list(occupancies)
        speeds = list(speeds) if speeds is not None else [1.0] * n
        for _ in range(self.cfg.max_concurrent):
            # stragglers drain first
            stragglers = [i for i in range(n)
                          if speeds[i] < self.cfg.straggler_speed and occ[i] > 0]
            if stragglers:
                src = max(stragglers, key=lambda i: occ[i])
            else:
                src = max(range(n), key=lambda i: occ[i])
            dst = min(range(n), key=lambda i: occ[i] if speeds[i] >= 1.0 else 2.0)
            if src == dst:
                break
            if not stragglers and occ[src] - occ[dst] < self.cfg.imbalance_threshold:
                break
            moves.append((src, dst))
            delta = 1.0 / max(n, 1)
            occ[src] -= delta
            occ[dst] += delta
        return moves

    def transfer_time(self, nbytes: int) -> float:
        return nbytes / self.cfg.bandwidth_Bps + self.cfg.overhead_s

    # ------------------------------------------------------------ execution
    def migrate(self, src: InferenceEngine, dst: InferenceEngine, rid: int,
                now: float, src_idx: int = 0, dst_idx: int = 1) -> MigrationEvent | None:
        """Real engine-to-engine handoff (same model config/max_len)."""
        if getattr(src, "paged", False) or getattr(dst, "paged", False):
            # paged migration payloads (block-table handoff) are an open
            # edge — see ROADMAP.md; the control loop skips these replicas
            return None
        nbytes = src.kv_bytes(rid)
        req, payload = src.extract_row(rid)
        if not dst.adopt(req, payload, now):
            # destination full: roll back
            assert src.adopt(req, payload, now), "rollback failed"
            return None
        ev = MigrationEvent(now, rid, src_idx, dst_idx, nbytes,
                            self.transfer_time(nbytes))
        self.events.append(ev)
        return ev

    def pick_request(self, eng: InferenceEngine) -> int | None:
        """Cheapest-to-move live request (smallest progress => smallest
        dead time); ties by shortest remaining work."""
        if not eng.row_req:
            return None
        req = min(eng.row_req.values(), key=lambda r: len(r.output))
        return req.rid
