"""Transparent request migration (paper §3 'Migration technology').

Llumnix/DistServe-inspired: live requests move between replicas to
(a) rebalance load, (b) drain stragglers/failing nodes, (c) defragment KV
capacity.  The decision layer is shared by the simulator and the real
engines; the handoff itself is InferenceEngine.extract_row/adopt with a
transfer-time cost model:

    t_handoff = kv_bytes / bw + overhead

bw = NVLink-class intra-node (the paper's testbed) or ICI/DCN on TPU pods.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.serving.engine import InferenceEngine
from repro.serving.events import PreemptEvent
from repro.serving.request import State


@dataclasses.dataclass
class MigrationConfig:
    imbalance_threshold: float = 0.35   # (max-min)/capacity occupancy gap
    straggler_speed: float = 0.5        # below this, drain the replica
    bandwidth_Bps: float = 200e9        # NVLink-ish; TPU ICI ~50e9/link
    overhead_s: float = 0.010
    max_concurrent: int = 2


@dataclasses.dataclass
class MigrationEvent:
    t: float
    rid: int
    src: int
    dst: int
    bytes: int                  # actually transferred (dst-cached blocks skipped)
    duration_s: float
    bytes_full: int = 0         # the request's full KV footprint at the source
    blocks_skipped: int = 0     # dst prefix-cache hits (paged only)
    phase: str = "decode"       # "decode" | "prefill" (chunk-boundary handoff)


@dataclasses.dataclass
class MigrationFailure:
    t: float
    rid: int
    src: int
    dst: int
    reason: str                 # "dst-full" | "requeued" | "backend-mismatch"


class MigrationManager:
    def __init__(self, cfg: MigrationConfig = MigrationConfig(),
                 transfer_span: str = "migration_transfer"):
        self.cfg = cfg
        # span name a successful handoff is annotated with on the request's
        # trace: "migration_transfer" for rebalance/drain moves,
        # "handoff" when the disaggregated server owns this manager
        self.transfer_span = transfer_span
        self.events: list[MigrationEvent] = []
        self.failures: list[MigrationFailure] = []
        self.attempted = 0
        self._m_attempts = self._m_success = self._m_failures = None
        self._m_bytes = self._m_bytes_full = self._m_blocks_skipped = None

    def attach_metrics(self, registry) -> None:
        """Bind migration instruments onto a cluster metrics registry."""
        self._m_attempts = registry.counter(
            "migration_attempts_total", "Handoffs attempted")
        self._m_success = registry.counter(
            "migration_success_total", "Handoffs completed, by phase",
            ("phase",))
        self._m_failures = registry.counter(
            "migration_failures_total", "Handoffs failed, by reason",
            ("reason",))
        self._m_bytes = registry.counter(
            "migration_bytes_total",
            "KV bytes actually transferred (dst-cached blocks skipped)")
        self._m_bytes_full = registry.counter(
            "migration_bytes_full_total",
            "Full KV footprint of migrated requests")
        self._m_blocks_skipped = registry.counter(
            "migration_blocks_skipped_total",
            "Blocks not shipped because the destination already cached them")

    @property
    def succeeded(self) -> int:
        return len(self.events)

    @property
    def failed(self) -> int:
        return len(self.failures)

    # ------------------------------------------------------------ decision
    def plan(self, occupancies: Sequence[float],
             speeds: Sequence[float] | None = None) -> list[tuple[int, int]]:
        """Return (src_replica, dst_replica) moves given per-replica
        occupancy fractions (and optional speed factors for stragglers)."""
        n = len(occupancies)
        if n < 2:
            return []
        moves: list[tuple[int, int]] = []
        occ = list(occupancies)
        speeds = list(speeds) if speeds is not None else [1.0] * n
        for _ in range(self.cfg.max_concurrent):
            # stragglers drain first
            stragglers = [i for i in range(n)
                          if speeds[i] < self.cfg.straggler_speed and occ[i] > 0]
            if stragglers:
                src = max(stragglers, key=lambda i: occ[i])
            else:
                src = max(range(n), key=lambda i: occ[i])
            dst = min(range(n), key=lambda i: occ[i] if speeds[i] >= 1.0 else 2.0)
            if src == dst:
                break
            if not stragglers and occ[src] - occ[dst] < self.cfg.imbalance_threshold:
                break
            moves.append((src, dst))
            delta = 1.0 / max(n, 1)
            occ[src] -= delta
            occ[dst] += delta
        return moves

    def transfer_time(self, nbytes: int) -> float:
        return nbytes / self.cfg.bandwidth_Bps + self.cfg.overhead_s

    # ------------------------------------------------------------ execution
    def _fail(self, now: float, rid: int, src_idx: int, dst_idx: int,
              reason: str) -> None:
        self.failures.append(MigrationFailure(now, rid, src_idx, dst_idx, reason))
        if self._m_failures is not None:
            self._m_failures.inc(reason=reason)

    def migrate(self, src: InferenceEngine, dst: InferenceEngine, rid: int,
                now: float, src_idx: int = 0, dst_idx: int = 1) -> MigrationEvent | None:
        """Real engine-to-engine handoff (same model config/max_len).

        Paged replicas hand off their block table: the destination is probed
        first, so blocks whose token content its prefix cache already holds
        are never transferred — a prefix-cache-hot request moves fewer bytes
        than its full KV footprint.  Payloads do not convert across KV
        backends, so a dense<->paged pair is recorded as a failure and
        skipped.

        A destination refusal (no row / no admissible block plan) rolls the
        request back into the source.  If the source *also* cannot re-admit
        — its row or blocks were claimed meanwhile — the request is requeued
        at the source scheduler from scratch rather than silently dropped
        (on a paged source its prompt KV was donated to the prefix index at
        extraction, so the re-prefill is mostly cache hits).  Every failure
        is recorded in :attr:`failures` with a reason."""
        self.attempted += 1
        if self._m_attempts is not None:
            self._m_attempts.inc()
        src_paged = getattr(src, "paged", False)
        if src_paged != getattr(dst, "paged", False):
            self._fail(now, rid, src_idx, dst_idx, "backend-mismatch")
            return None
        _, live_req, _ = src._find_row(rid)
        n_valid = len(src.migration_sequence(rid))
        nbytes_full = src.kv_bytes(rid)
        nbytes, skipped = nbytes_full, 0
        if src_paged and getattr(dst, "prefix_enabled", False):
            # probe the destination: aligned full blocks it already caches
            # are reused there, not sent (adopt performs the same walk)
            seq = src.migration_sequence(rid)
            skipped = dst.prefix.lookup(seq) // dst.block_size
            nbytes = nbytes_full - skipped * src.kv_per_block_bytes()
        if not dst.can_adopt(live_req, n_valid, skipped):
            # cheap refusal: no KV was gathered, nothing to roll back —
            # a drain loop can retry every tick at O(1) cost
            self._fail(now, rid, src_idx, dst_idx, "dst-full")
            return None
        req, payload = src.extract_row(rid, now=now)
        if not dst.adopt(req, payload, now):
            if src.adopt(req, payload, now):
                self._fail(now, rid, src_idx, dst_idx, "dst-full")
            else:
                # the source can no longer re-admit either: requeue the
                # request explicitly — a live request is never dropped.
                # Appended directly: max_queue caps *new* arrivals, not a
                # rolled-back request that was already being served
                req.state = State.QUEUED
                req.row = None
                req.output.clear()
                req.token_times.clear()
                req.t_first_token = None
                req.t_admit = None
                req.preemptions += 1
                src.scheduler.queue.append(req)
                # the extract closed the phase span; the request is queued
                # again, so its trace re-enters queue residency here
                src.tracer.begin(rid, "queue_wait", now,
                                 replica=getattr(src, "_rlabel", None),
                                 requeued=True)
                # stream consumers: earlier token indices will be re-emitted
                # by whichever replica re-serves this request — the demux
                # drops them, keeping downstream streams append-only
                src.emit_event(PreemptEvent(t=now, rid=rid, reason="requeued"))
                self._fail(now, rid, src_idx, dst_idx, "requeued")
            return None
        ev = MigrationEvent(now, rid, src_idx, dst_idx, nbytes,
                            self.transfer_time(nbytes), bytes_full=nbytes_full,
                            blocks_skipped=skipped, phase=payload["phase"])
        self.events.append(ev)
        # the KV handoff on the request's trace: an instant span on the step
        # clock carrying the modeled transfer cost as an attribute (the
        # attribution report charges duration_s to the migration bucket)
        dst.tracer.annotate(rid, self.transfer_span, now,
                            replica=getattr(dst, "_rlabel", None),
                            src=src_idx, dst=dst_idx, bytes=nbytes,
                            bytes_full=nbytes_full, blocks_skipped=skipped,
                            duration_s=ev.duration_s)
        if src.tracer is not dst.tracer:
            # replicas with independent tracers each keep their slice of the
            # trace (same trace id, disjoint span ids); close the source's
            # so no span is left open on a replica that no longer serves it
            src.tracer.finish(rid, now, status="migrated-out")
        if self._m_attempts is not None:
            self._m_success.inc(phase=payload["phase"])
            self._m_bytes.inc(nbytes)
            self._m_bytes_full.inc(nbytes_full)
            self._m_blocks_skipped.inc(skipped)
        return ev

    def pick_request(self, eng: InferenceEngine,
                     include_prefill: bool = True) -> int | None:
        """Cheapest-to-move live request — smallest materialised KV
        (``pos``), so the handoff moves the least data and loses the least
        progress if it fails.  Candidates come from
        :meth:`InferenceEngine.migratable_requests`: decode rows plus, when
        ``include_prefill``, chunk-boundary mid-prefill rows — the payload
        carries the prefill progress, so adopting one resumes its remaining
        prompt instead of truncating it into a bogus decode."""
        cands = eng.migratable_requests()
        if not include_prefill:
            cands = [r for r in cands if r.state is State.DECODE]
        if not cands:
            return None
        req = min(cands, key=lambda r: int(eng.pos[r.row]))
        return req.rid
