"""Transparent request migration (paper §3 'Migration technology').

Llumnix/DistServe-inspired: live requests move between replicas to
(a) rebalance load, (b) drain stragglers/failing nodes, (c) defragment KV
capacity.  The decision layer is shared by the simulator and the real
engines; the handoff itself is InferenceEngine.extract_row/adopt with a
transfer-time cost model:

    t_handoff = kv_bytes * concurrent / bw + overhead

bw = NVLink-class intra-node (the paper's testbed) or ICI/DCN on TPU pods;
``concurrent`` transfers sharing one link split its bandwidth.

Two execution paths share the probe/extract/convert/rollback logic:

* :meth:`MigrationManager.migrate` — the synchronous whole-payload
  handoff (extract_row -> adopt in one call), with the modeled cost.
* :meth:`MigrationManager.migrate_async` — the cloud-native path: the
  destination reserves its row and block plan up front
  (``begin_adopt``), then the payload streams over a
  :class:`~repro.core.transport.Transport` link one block-granular chunk
  per message (``feed_adopt``), and the row activates
  (``commit_adopt``) as soon as the last chunk lands — transfer
  overlapped with compute on both replicas instead of stop-and-copy.
  ``duration_s`` on the resulting event is *measured* in transport steps,
  so link latency, serialization and contention all show up in it.

Payloads convert across KV backends (dense row -> destination blocks and
back); ``backend-mismatch`` remains only for genuinely unservable shapes
(cache leaves with no KV sequence axis — SSM state has no block form).
``dst-full`` refusals are tracked per request with capped exponential
backoff so the control plane retries them on a later tick instead of
abandoning the move.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax

from repro.serving.engine import InferenceEngine
from repro.serving.events import PreemptEvent
from repro.serving.request import State


@dataclasses.dataclass
class MigrationConfig:
    imbalance_threshold: float = 0.35   # (max-min)/capacity occupancy gap
    straggler_speed: float = 0.5        # below this, drain the replica
    bandwidth_Bps: float = 200e9        # NVLink-ish; TPU ICI ~50e9/link
    overhead_s: float = 0.010
    # concurrent transfers allowed *per link* (per (src, dst) replica pair)
    max_concurrent: int = 2
    # capped exponential backoff for dst-full refusals: attempt k retries
    # after base * backoff^(k-1) steps, capped; abandoned past max_attempts
    retry_base_steps: float = 2.0
    retry_backoff: float = 2.0
    retry_cap_steps: float = 32.0
    retry_max_attempts: int = 5


@dataclasses.dataclass
class MigrationEvent:
    t: float
    rid: int
    src: int
    dst: int
    bytes: int                  # actually transferred (dst-cached blocks skipped)
    duration_s: float
    bytes_full: int = 0         # the request's full KV footprint at the source
    blocks_skipped: int = 0     # dst prefix-cache hits (paged only)
    phase: str = "decode"       # "decode" | "prefill" (chunk-boundary handoff)
    chunks: int = 1             # transfer granularity (async: one per block)


@dataclasses.dataclass
class MigrationFailure:
    t: float
    rid: int
    src: int
    dst: int
    reason: str                 # "dst-full" | "requeued" | "backend-mismatch"


@dataclasses.dataclass
class _AsyncTransfer:
    """One in-flight block-granular migration (extract done, commit pending)."""
    rid: int
    req: Any
    dst: InferenceEngine
    ticket: int
    payload: dict
    src_node: str
    dst_node: str
    src_idx: int
    dst_idx: int
    src_tracer: Any
    n_keep: int
    total: int                  # chunks to ship
    chunk_bytes: int
    nbytes: int
    nbytes_full: int
    phase: str
    t0: float                   # caller clock at initiation
    step0: int                  # transport clock at initiation
    sent: int = 0
    received: int = 0


class MigrationManager:
    #: transport message kind KV chunks travel under
    CHUNK_KIND = "kv_chunk"

    def __init__(self, cfg: MigrationConfig = MigrationConfig(),
                 transfer_span: str = "migration_transfer"):
        self.cfg = cfg
        # span name a successful handoff is annotated with on the request's
        # trace: "migration_transfer" for rebalance/drain moves,
        # "handoff" when the disaggregated server owns this manager
        self.transfer_span = transfer_span
        self.events: list[MigrationEvent] = []
        self.failures: list[MigrationFailure] = []
        self.attempted = 0
        # (dst_node, rid) -> in-flight async transfer
        self._inflight: dict[tuple[str, int], _AsyncTransfer] = {}
        # rid -> {"attempts", "next_try"} backoff state for dst-full refusals
        self._retry: dict[int, dict[str, float]] = {}
        self._m_attempts = self._m_success = self._m_failures = None
        self._m_bytes = self._m_bytes_full = self._m_blocks_skipped = None

    def attach_metrics(self, registry) -> None:
        """Bind migration instruments onto a cluster metrics registry."""
        self._m_attempts = registry.counter(
            "migration_attempts_total", "Handoffs attempted")
        self._m_success = registry.counter(
            "migration_success_total", "Handoffs completed, by phase",
            ("phase",))
        self._m_failures = registry.counter(
            "migration_failures_total", "Handoffs failed, by reason",
            ("reason",))
        self._m_bytes = registry.counter(
            "migration_bytes_total",
            "KV bytes actually transferred (dst-cached blocks skipped)")
        self._m_bytes_full = registry.counter(
            "migration_bytes_full_total",
            "Full KV footprint of migrated requests")
        self._m_blocks_skipped = registry.counter(
            "migration_blocks_skipped_total",
            "Blocks not shipped because the destination already cached them")

    @property
    def succeeded(self) -> int:
        return len(self.events)

    @property
    def failed(self) -> int:
        return len(self.failures)

    @property
    def transfers_in_flight(self) -> int:
        return len(self._inflight)

    # ------------------------------------------------------------ decision
    def plan(self, occupancies: Sequence[float],
             speeds: Sequence[float] | None = None) -> list[tuple[int, int]]:
        """Return (src_replica, dst_replica) moves given per-replica
        occupancy fractions (and optional speed factors for stragglers).
        At most ``max_concurrent`` moves per tick — which also caps every
        link at ``max_concurrent``, the number of transfers it carries
        concurrently.  (The cap is *enforced* per link at transfer time:
        ``migrate_async`` refuses a saturated link, and the sync path's
        ``concurrent`` argument stretches ``duration_s`` for moves that
        share one.)"""
        n = len(occupancies)
        if n < 2:
            return []
        moves: list[tuple[int, int]] = []
        occ = list(occupancies)
        speeds = list(speeds) if speeds is not None else [1.0] * n
        for _ in range(self.cfg.max_concurrent):
            # stragglers drain first
            stragglers = [i for i in range(n)
                          if speeds[i] < self.cfg.straggler_speed and occ[i] > 0]
            if stragglers:
                src = max(stragglers, key=lambda i: occ[i])
            else:
                src = max(range(n), key=lambda i: occ[i])
            dst = min(range(n), key=lambda i: occ[i] if speeds[i] >= 1.0 else 2.0)
            if src == dst:
                break
            if not stragglers and occ[src] - occ[dst] < self.cfg.imbalance_threshold:
                break
            moves.append((src, dst))
            delta = 1.0 / max(n, 1)
            occ[src] -= delta
            occ[dst] += delta
        return moves

    def transfer_time(self, nbytes: int, concurrent: int = 1) -> float:
        """Modeled handoff cost; ``concurrent`` transfers on the same link
        split its bandwidth, so each one serializes ``concurrent`` times
        slower (the async path doesn't use this — contention emerges from
        the transport's fair-share crediting and is *measured* instead)."""
        return nbytes * max(concurrent, 1) / self.cfg.bandwidth_Bps \
            + self.cfg.overhead_s

    # ------------------------------------------------------- retry/backoff
    def _note_refusal(self, rid: int, now: float) -> None:
        st = self._retry.setdefault(rid, {"attempts": 0, "next_try": 0.0})
        st["attempts"] += 1
        delay = min(self.cfg.retry_base_steps
                    * self.cfg.retry_backoff ** (st["attempts"] - 1),
                    self.cfg.retry_cap_steps)
        st["next_try"] = now + delay

    def retry_state(self, rid: int) -> dict[str, float] | None:
        return self._retry.get(rid)

    def clear_retry(self, rid: int) -> None:
        self._retry.pop(rid, None)

    def ready_to_retry(self, now: float) -> list[int]:
        """Requests whose dst-full backoff has elapsed and that still have
        retry budget — the control plane re-plans a move for each."""
        return [rid for rid, st in self._retry.items()
                if st["attempts"] < self.cfg.retry_max_attempts
                and st["next_try"] <= now]

    # ------------------------------------------------------------ execution
    def _fail(self, now: float, rid: int, src_idx: int, dst_idx: int,
              reason: str) -> None:
        self.failures.append(MigrationFailure(now, rid, src_idx, dst_idx, reason))
        if self._m_failures is not None:
            self._m_failures.inc(reason=reason)
        if reason == "dst-full":
            self._note_refusal(rid, now)
        elif reason == "requeued":
            # the request restarts from the queue; the old move is moot
            self.clear_retry(rid)

    def _probe(self, src: InferenceEngine, dst: InferenceEngine, rid: int):
        """Shared pre-transfer probe: payload size, and the full blocks the
        destination's prefix cache already holds (reused, never shipped)."""
        nbytes_full = src.kv_bytes(rid)
        nbytes, skipped = nbytes_full, 0
        if (getattr(src, "paged", False) and getattr(dst, "paged", False)
                and getattr(dst, "prefix_enabled", False)):
            seq = src.migration_sequence(rid)
            skipped = dst.prefix.lookup(seq) // dst.block_size
            nbytes = nbytes_full - skipped * src.kv_per_block_bytes()
        return nbytes, nbytes_full, skipped

    def _rollback(self, src: InferenceEngine, req, payload: dict, rid: int,
                  now: float, src_idx: int, dst_idx: int) -> None:
        """Destination refused after extraction: re-adopt at the source
        (with the *original* payload — its backend, not the converted one),
        or requeue from scratch if the source can't re-admit either — a
        live request is never dropped."""
        if src.adopt(req, payload, now):
            self._fail(now, rid, src_idx, dst_idx, "dst-full")
        else:
            # Appended directly: max_queue caps *new* arrivals, not a
            # rolled-back request that was already being served
            req.state = State.QUEUED
            req.row = None
            req.output.clear()
            req.token_times.clear()
            req.t_first_token = None
            req.t_admit = None
            req.preemptions += 1
            src.scheduler.queue.append(req)
            # the extract closed the phase span; the request is queued
            # again, so its trace re-enters queue residency here
            src.tracer.begin(rid, "queue_wait", now,
                             replica=getattr(src, "_rlabel", None),
                             requeued=True)
            # stream consumers: earlier token indices will be re-emitted
            # by whichever replica re-serves this request — the demux
            # drops them, keeping downstream streams append-only
            src.emit_event(PreemptEvent(t=now, rid=rid, reason="requeued"))
            self._fail(now, rid, src_idx, dst_idx, "requeued")

    def _record(self, ev: MigrationEvent, rid: int, dst: InferenceEngine,
                src_tracer, now: float, skipped: int) -> None:
        self.events.append(ev)
        self.clear_retry(rid)
        # the KV handoff on the request's trace: an instant span on the step
        # clock carrying the transfer cost as an attribute (the attribution
        # report charges duration_s to the migration bucket)
        dst.tracer.annotate(rid, self.transfer_span, now,
                            replica=getattr(dst, "_rlabel", None),
                            src=ev.src, dst=ev.dst, bytes=ev.bytes,
                            bytes_full=ev.bytes_full, blocks_skipped=skipped,
                            duration_s=ev.duration_s, chunks=ev.chunks)
        if src_tracer is not dst.tracer:
            # replicas with independent tracers each keep their slice of the
            # trace (same trace id, disjoint span ids); close the source's
            # so no span is left open on a replica that no longer serves it
            src_tracer.finish(rid, now, status="migrated-out")
        if self._m_attempts is not None:
            self._m_success.inc(phase=ev.phase)
            self._m_bytes.inc(ev.bytes)
            self._m_bytes_full.inc(ev.bytes_full)
            self._m_blocks_skipped.inc(skipped)

    def _converted(self, dst: InferenceEngine, req, payload: dict):
        """Payload in the destination's backend layout (identity when the
        backends already match)."""
        want = "paged" if getattr(dst, "paged", False) else "dense"
        if payload.get("kind", "dense") == want:
            return payload
        return dst.convert_payload(req, payload)

    def migrate(self, src: InferenceEngine, dst: InferenceEngine, rid: int,
                now: float, src_idx: int = 0, dst_idx: int = 1,
                concurrent: int = 1) -> MigrationEvent | None:
        """Real engine-to-engine handoff (same model config/max_len).

        Paged replicas hand off their block table: the destination is probed
        first, so blocks whose token content its prefix cache already holds
        are never transferred — a prefix-cache-hot request moves fewer bytes
        than its full KV footprint.  Dense<->paged pairs convert the payload
        in flight; only genuinely unservable shapes (no KV sequence axis to
        blockify) are recorded as ``backend-mismatch`` and skipped.

        A destination refusal (no row / no admissible block plan) rolls the
        request back into the source.  If the source *also* cannot re-admit
        — its row or blocks were claimed meanwhile — the request is requeued
        at the source scheduler from scratch rather than silently dropped
        (on a paged source its prompt KV was donated to the prefix index at
        extraction, so the re-prefill is mostly cache hits).  Every failure
        is recorded in :attr:`failures` with a reason; ``dst-full`` arms the
        retry backoff.  ``concurrent``: how many transfers share this link
        this tick — their modeled durations stretch accordingly."""
        self.attempted += 1
        if self._m_attempts is not None:
            self._m_attempts.inc()
        if getattr(src, "paged", False) != getattr(dst, "paged", False) \
                and not dst.can_convert(src):
            self._fail(now, rid, src_idx, dst_idx, "backend-mismatch")
            return None
        _, live_req, _ = src._find_row(rid)
        n_valid = len(src.migration_sequence(rid))
        nbytes, nbytes_full, skipped = self._probe(src, dst, rid)
        if not dst.can_adopt(live_req, n_valid, skipped):
            # cheap refusal: no KV was gathered, nothing to roll back —
            # a drain loop can retry every tick at O(1) cost
            self._fail(now, rid, src_idx, dst_idx, "dst-full")
            return None
        req, payload = src.extract_row(rid, now=now)
        converted = self._converted(dst, req, payload)
        if converted is None or not dst.adopt(req, converted, now):
            self._rollback(src, req, payload, rid, now, src_idx, dst_idx)
            return None
        ev = MigrationEvent(now, rid, src_idx, dst_idx, nbytes,
                            self.transfer_time(nbytes, concurrent),
                            bytes_full=nbytes_full,
                            blocks_skipped=skipped, phase=payload["phase"])
        self._record(ev, rid, dst, src.tracer, now, skipped)
        return ev

    # ------------------------------------------------- async (transported)
    def link_active(self, src_node: str, dst_node: str) -> int:
        return sum(1 for tr in self._inflight.values()
                   if tr.src_node == src_node and tr.dst_node == dst_node)

    def migrate_async(self, src: InferenceEngine, dst: InferenceEngine,
                      rid: int, now: float, transport, src_node: str,
                      dst_node: str, src_idx: int = 0,
                      dst_idx: int = 1) -> bool:
        """Start a block-granular handoff over a transport link: probe and
        extract at the source, reserve the row + block plan at the
        destination (``begin_adopt``), then hand the payload to
        :meth:`pump`, which streams one chunk per message under the link's
        backpressure.  The destination activates the row the moment the
        last chunk lands — both replicas keep stepping meanwhile.

        Returns True when the transfer is in flight.  False: the link
        already carries ``max_concurrent`` transfers (not a failure — retry
        next tick), or the same refusals as :meth:`migrate` (recorded in
        :attr:`failures`, dst-full arming the backoff).  Chunks travel
        reliably: faults injected on the unreliable class never corrupt KV,
        and a partition stalls — never kills — an in-flight adoption."""
        if self.link_active(src_node, dst_node) >= self.cfg.max_concurrent:
            return False
        if any(tr.rid == rid for tr in self._inflight.values()):
            return False
        self.attempted += 1
        if self._m_attempts is not None:
            self._m_attempts.inc()
        if getattr(src, "paged", False) != getattr(dst, "paged", False) \
                and not dst.can_convert(src):
            self._fail(now, rid, src_idx, dst_idx, "backend-mismatch")
            return False
        _, live_req, _ = src._find_row(rid)
        n_valid = len(src.migration_sequence(rid))
        nbytes, nbytes_full, skipped = self._probe(src, dst, rid)
        if not dst.can_adopt(live_req, n_valid, skipped):
            self._fail(now, rid, src_idx, dst_idx, "dst-full")
            return False
        req, payload = src.extract_row(rid, now=now)
        converted = self._converted(dst, req, payload)
        ticket = None
        if converted is not None:
            ticket = dst.begin_adopt(req, converted, now)
        if ticket is None:
            self._rollback(src, req, payload, rid, now, src_idx, dst_idx)
            return False
        st = dst._pending_adopt[ticket]
        if converted.get("kind") == "paged":
            total = st["expected"]
            chunk_bytes = dst.kv_per_block_bytes()
            nbytes = chunk_bytes * total    # post-plan truth (n_keep reused)
            skipped = st["n_keep"]
        else:
            total, chunk_bytes = 1, nbytes
        transport.register(dst_node, self.CHUNK_KIND, self._on_chunk)
        self._inflight[(dst_node, rid)] = _AsyncTransfer(
            rid=rid, req=req, dst=dst, ticket=ticket, payload=converted,
            src_node=src_node, dst_node=dst_node, src_idx=src_idx,
            dst_idx=dst_idx, src_tracer=src.tracer, n_keep=st["n_keep"],
            total=total, chunk_bytes=chunk_bytes, nbytes=nbytes,
            nbytes_full=nbytes_full, phase=payload["phase"], t0=now,
            step0=transport.now)
        self.pump(now, transport)
        return True

    def _chunk_data(self, tr: _AsyncTransfer, i: int):
        if tr.payload.get("kind") != "paged":
            return tr.payload["caches"]
        axes = tr.dst._pool_block_axes
        tree = tr.payload["blocks"]
        leaves = [jax.lax.slice_in_dim(d, tr.n_keep + i, tr.n_keep + i + 1,
                                       axis=ax)
                  for d, ax in zip(jax.tree.leaves(tree), axes)]
        return jax.tree.unflatten(jax.tree.structure(tree), leaves)

    def pump(self, now: float, transport) -> int:
        """Push pending chunks of every in-flight transfer onto their links,
        stopping per transfer at the first backpressured send.  Called once
        per control-plane step.  Returns chunks enqueued."""
        pushed = 0
        for tr in list(self._inflight.values()):
            while tr.sent < tr.total:
                data = self._chunk_data(tr, tr.sent)
                ok = transport.send(
                    tr.src_node, tr.dst_node, self.CHUNK_KIND,
                    {"rid": tr.rid, "i": tr.sent, "data": data},
                    size_bytes=tr.chunk_bytes, reliable=True)
                if not ok:
                    break
                tr.sent += 1
                pushed += 1
        return pushed

    def _on_chunk(self, msg, step_now: int) -> None:
        p = msg.payload
        tr = self._inflight.get((msg.dst, p["rid"]))
        if tr is None:
            return
        tr.dst.feed_adopt(tr.ticket, p["i"], p["data"])
        tr.received += 1
        # map the transport clock back onto the caller's step clock
        now = tr.t0 + (step_now - tr.step0)
        tr.dst.tracer.annotate(tr.rid, f"{self.transfer_span}_chunk", now,
                               replica=getattr(tr.dst, "_rlabel", None),
                               chunk=p["i"], chunks=tr.total,
                               bytes=tr.chunk_bytes,
                               src=tr.src_idx, dst=tr.dst_idx)
        if tr.received < tr.total:
            return
        del self._inflight[(msg.dst, p["rid"])]
        tr.dst.commit_adopt(tr.ticket, now)
        ev = MigrationEvent(tr.t0, tr.rid, tr.src_idx, tr.dst_idx, tr.nbytes,
                            duration_s=float(step_now - tr.step0),
                            bytes_full=tr.nbytes_full,
                            blocks_skipped=tr.n_keep, phase=tr.phase,
                            chunks=tr.total)
        self._record(ev, tr.rid, tr.dst, tr.src_tracer, now, tr.n_keep)

    def pick_request(self, eng: InferenceEngine,
                     include_prefill: bool = True) -> int | None:
        """Cheapest-to-move live request — smallest materialised KV
        (``pos``), so the handoff moves the least data and loses the least
        progress if it fails.  Candidates come from
        :meth:`InferenceEngine.migratable_requests`: decode rows plus, when
        ``include_prefill``, chunk-boundary mid-prefill rows — the payload
        carries the prefill progress, so adopting one resumes its remaining
        prompt instead of truncating it into a bogus decode."""
        cands = eng.migratable_requests()
        if not include_prefill:
            cands = [r for r in cands if r.state is State.DECODE]
        if not cands:
            return None
        req = min(cands, key=lambda r: int(eng.pos[r.row]))
        return req.rid
