"""Model-endpoint registry: multi-model, multi-tenant serving with
scale-to-zero (paper §2 "containerized model services").

One cluster hosts several *endpoints* — model variants with their own
replica sets, cache directories, and autoscaler policies — behind one
control plane.  :class:`ModelEndpoint` is the declarative spec (the
paper's per-service deployment manifest: model config, KV backend,
priority class, replica bounds); :class:`EndpointRegistry` owns one
:class:`~repro.core.orchestrator.Orchestrator` per endpoint while
sharing the things the paper shares cluster-wide:

* one logical step clock — ``registry.step(now)`` advances every
  endpoint and the transport fabric exactly once,
* one :class:`~repro.core.transport.Transport` — endpoints namespace
  their nodes as ``"{name}/r0"``/``"{name}/ctrl"``,
* one Tracer + MetricsRegistry — every series carries an
  ``{endpoint=...}`` label,
* one admission surface with per-tenant quotas
  (:class:`TenantQuota`) — the weighted-fair scheduler policy
  (``SchedulerConfig(policy="wfq")``) divides each replica's admission
  bandwidth by tenant weight.

Scale-to-zero (``min_replicas=0``): the endpoint starts with no
replicas; the first request spawns one (`checkpoint-load + compile`
measured as ``cold_start_s`` wall seconds and ``cold_start_steps``
logical steps, traced as a ``cold_start`` span) and *queues* behind the
warm-up rather than rejecting; ``idle_ticks_to_zero`` quiet control
ticks tear the replica set back down.

Priority classes: under a cluster replica budget, an endpoint that
needs a replica may evict the coolest replica of a *lower-priority*
endpoint — drain/migration inside the victim endpoint, plain teardown
across endpoints (different models: KV cannot migrate).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable

from repro.core.autoscaler import HPAConfig
from repro.core.metrics import MetricsRegistry
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.scaling_policy import ProactiveConfig
from repro.core.tracing import Tracer
from repro.core.transport import Transport
from repro.serving.request import Request, State
from repro.serving.scheduler import SchedulerConfig

# endpoint_state gauge encoding (gauges carry floats, not strings)
STATE_CODES = {"scaled_to_zero": 0, "cold": 1, "ready": 2}


@dataclasses.dataclass
class ModelEndpoint:
    """Declarative endpoint spec — everything the registry needs to run
    one model variant as a replica set.  ``model`` is a
    :class:`~repro.models.ModelConfig` (the default engine factory builds
    :class:`~repro.serving.engine.InferenceEngine` from it); pass
    ``make_engine`` instead for full control of engine construction."""
    name: str
    model: Any = None                       # ModelConfig for the default factory
    make_engine: Callable[[], Any] | None = None
    kv_backend: str = "dense"               # "dense" | "paged"
    # priority class: under a cluster replica budget a higher-priority
    # endpoint may evict a strictly lower-priority endpoint's coolest replica
    priority: int = 0
    min_replicas: int = 1                   # 0 => scale-to-zero endpoint
    max_replicas: int = 4
    hpa: HPAConfig | None = None            # None => queue-depth HPA default
    # proactive goodput-driven scaling (core/scaling_policy.py): when set,
    # this endpoint's desired replica counts come from the forecast +
    # capacity + goodput planner instead of the reactive HPA ratio law
    scaling: ProactiveConfig | None = None
    lb_policy: str = "least"
    sched: SchedulerConfig | None = None    # e.g. policy="wfq" + tenant_weights
    # engine shape (default factory only)
    capacity: int = 4
    max_len: int = 64
    buckets: tuple[int, ...] = (8, 16)
    block_size: int = 16
    seed: int = 7
    # cold start: logical steps a fresh replica warms before serving
    cold_start_steps: int = 2
    # quiet control ticks before a min_replicas=0 endpoint scales to zero
    idle_ticks_to_zero: int = 3
    control_every_steps: int = 4

    def engine_factory(self) -> Callable[[], Any]:
        if self.make_engine is not None:
            return self.make_engine
        if self.model is None:
            raise ValueError(
                f"endpoint {self.name!r}: need a model config or make_engine")
        spec = self

        def make():
            from repro.serving.engine import InferenceEngine
            kw = dict(capacity=spec.capacity, max_len=spec.max_len,
                      buckets=spec.buckets, kv_backend=spec.kv_backend,
                      block_size=spec.block_size, seed=spec.seed)
            if spec.sched is not None:
                kw["sched"] = dataclasses.replace(spec.sched)
            return InferenceEngine(spec.model, **kw)
        return make


@dataclasses.dataclass
class TenantQuota:
    """Per-tenant admission policy, shared across every endpoint.

    ``weight`` feeds the weighted-fair scheduler (a weight-3 tenant earns
    ~3x the admitted tokens of a weight-1 tenant under saturation);
    ``max_inflight`` hard-caps concurrently live requests — the
    (max_inflight+1)-th submit is rejected with
    ``tenant_rejections_total{reason="quota"}``."""
    weight: float = 1.0
    max_inflight: int | None = None


@dataclasses.dataclass
class _Endpoint:
    """Registry-internal runtime record for one endpoint."""
    spec: ModelEndpoint
    orch: Orchestrator
    cold_rid: int | None = None     # synthetic trace rid of the live cold start
    cold_begin_step: int = 0
    cold_wall_s: float = 0.0


class EndpointRegistry:
    """The multi-model control plane: routes by ``Request.model``, owns
    per-endpoint orchestrators, shares clock/fabric/observability, and
    enforces tenant quotas, priority eviction, and scale-to-zero."""

    def __init__(self, endpoints: tuple[ModelEndpoint, ...] | list = (),
                 *, transport: Transport | None = None,
                 cluster_max_replicas: int | None = None,
                 tenants: dict[str, TenantQuota] | None = None,
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.transport = transport
        # cluster-wide replica budget.  None = unbounded: endpoints only
        # honor their own max_replicas and eviction never triggers.
        self.cluster_max_replicas = cluster_max_replicas
        self.tenants: dict[str, TenantQuota] = dict(tenants or {})
        self._eps: dict[str, _Endpoint] = {}
        self._steps = 0
        self._now = 0.0
        # cold-start spans need trace ids that can never collide with real
        # request rids — synthetic negative rids
        self._cold_rids = itertools.count(start=-1, step=-1)
        # quota accounting: live requests per tenant (pruned as they finish)
        self._live: dict[int, Request] = {}
        self._inflight: dict[str, set[int]] = {}
        m = self.metrics
        self._c_requests = m.counter(
            "endpoint_requests_total", "Requests routed, by endpoint/tenant",
            ("endpoint", "tenant"))
        self._g_state = m.gauge(
            "endpoint_state",
            "Endpoint lifecycle (0=scaled_to_zero, 1=cold, 2=ready)",
            ("endpoint",))
        self._c_cold = m.counter(
            "endpoint_cold_starts_total", "Scale-from-zero wakeups",
            ("endpoint",))
        self._g_cold_steps = m.gauge(
            "endpoint_cold_start_steps",
            "Logical steps the last cold start took (spawn -> first warm "
            "replica)", ("endpoint",))
        self._g_cold_s = m.gauge(
            "endpoint_cold_start_seconds",
            "Wall seconds of the last cold start's checkpoint-load + "
            "compile path", ("endpoint",))
        self._c_tenant_rej = m.counter(
            "tenant_rejections_total",
            "Registry-level admission rejections, by tenant",
            ("tenant", "reason"))
        self._c_evict = m.counter(
            "endpoint_evictions_total",
            "Priority evictions: victim's replica torn down for claimant",
            ("victim", "claimant"))
        for spec in endpoints:
            self.add_endpoint(spec)

    # ---------------------------------------------------------- membership
    def add_endpoint(self, spec: ModelEndpoint) -> Orchestrator:
        if spec.name in self._eps:
            raise ValueError(f"endpoint {spec.name!r} already registered")
        if not spec.name:
            raise ValueError("endpoints need a non-empty name "
                             "(it is the metric label and route key)")
        hpa = spec.hpa if spec.hpa is not None else HPAConfig(
            metric="queue", target=4.0, min_replicas=max(1, spec.min_replicas),
            max_replicas=spec.max_replicas, stabilization_s=5.0,
            scale_down_cooldown_s=5.0)
        # the HPA law floors desired at 1, so its min_replicas floor is 1
        # even for scale-to-zero endpoints — reaching 0 is registry policy
        # (idle teardown), never an autoscaler decision
        hpa = dataclasses.replace(
            hpa, min_replicas=max(1, min(hpa.min_replicas, spec.max_replicas)),
            max_replicas=spec.max_replicas)
        cfg = OrchestratorConfig(
            name=spec.name, min_replicas=spec.min_replicas,
            max_replicas=spec.max_replicas, hpa=hpa,
            scaling=spec.scaling,
            lb_policy=spec.lb_policy,
            cold_start_steps=spec.cold_start_steps,
            idle_ticks_to_zero=spec.idle_ticks_to_zero,
            control_every_steps=spec.control_every_steps,
            transport=self.transport)
        orch = Orchestrator(spec.engine_factory(), cfg,
                            tracer=self.tracer, metrics=self.metrics)
        # autoscaler scale-ups go through the cluster budget (and may
        # trigger a priority eviction) exactly like first-request wakeups
        orch.replica_gate = lambda name=spec.name: self._admit_replica(
            name, self._now)
        self._eps[spec.name] = ep = _Endpoint(spec=spec, orch=orch)
        self._g_state.set(STATE_CODES[self.state(spec.name)],
                          endpoint=spec.name)
        return ep.orch

    def resolve(self, name: str | None) -> Orchestrator | None:
        """The endpoint's orchestrator, or None for an unknown model —
        the completions front-end turns None into an OpenAI-style
        ``invalid_request_error``."""
        if name is None:
            return None
        ep = self._eps.get(name)
        return ep.orch if ep is not None else None

    def names(self) -> list[str]:
        return sorted(self._eps)

    def state(self, name: str) -> str:
        """``ready`` (>=1 warm replica) | ``cold`` (replicas exist but all
        warming) | ``scaled_to_zero`` (no replicas)."""
        ep = self._eps[name]
        if not ep.orch.engines:
            return "scaled_to_zero"
        return "ready" if ep.orch.warm_replicas() > 0 else "cold"

    def describe(self, name: str) -> dict[str, Any]:
        ep = self._eps[name]
        return {"name": name, "state": self.state(name),
                "replicas": len(ep.orch.engines),
                "priority": ep.spec.priority}

    # ----------------------------------------------------------- capacity
    def total_replicas(self) -> int:
        return sum(len(ep.orch.engines) for ep in self._eps.values())

    def _admit_replica(self, name: str, now: float) -> bool:
        """May ``name`` add a replica?  Under budget: yes.  At the budget:
        only by evicting the coolest replica of a strictly lower-priority
        endpoint (emptiest victim endpoint first, so eviction prefers idle
        capacity over live work)."""
        if self.cluster_max_replicas is None or \
                self.total_replicas() < self.cluster_max_replicas:
            return True
        me = self._eps[name].spec.priority
        victims = sorted(
            (ep for ep in self._eps.values()
             if ep.spec.priority < me and ep.orch.engines),
            key=lambda ep: (ep.spec.priority, ep.orch.pending()))
        for vic in victims:
            if vic.orch.evict_coolest(now):
                self._c_evict.inc(victim=vic.spec.name, claimant=name)
                self._g_state.set(STATE_CODES[self.state(vic.spec.name)],
                                  endpoint=vic.spec.name)
                return True
        return False

    # ---------------------------------------------------------- admission
    def submit(self, req: Request, now: float | None = None) -> bool:
        """Route one request to its endpoint by ``req.model``.

        Returns False (with ``req.state = REJECTED``) on a tenant-quota or
        replica-budget rejection; raises KeyError for an unknown model —
        API callers pre-check with :meth:`resolve` and return the
        structured error DTO instead."""
        now = time.perf_counter() if now is None else now
        self._now = now
        ep = self._eps.get(req.model) if req.model is not None else None
        if ep is None:
            raise KeyError(f"unknown model {req.model!r}; "
                           f"available: {self.names()}")
        if req.tenant is None:
            req.tenant = "default"
        # arrival stamps *here*, not at the replica scheduler: a request
        # that waits out a cold start pays that wait in its TTFT
        if req.arrival is None:
            req.arrival = now
        q = self.tenants.get(req.tenant)
        if q is not None and q.max_inflight is not None:
            if len(self._inflight.get(req.tenant, ())) >= q.max_inflight:
                req.state = State.REJECTED
                self._c_tenant_rej.inc(tenant=req.tenant, reason="quota")
                return False
        if not ep.orch.engines:
            # scale-from-zero wakeup: the first request pays for (and
            # measures) the spin-up; it queues behind the warm-up below
            if not self._admit_replica(ep.spec.name, now):
                req.state = State.REJECTED
                self._c_tenant_rej.inc(tenant=req.tenant, reason="capacity")
                return False
            wall = ep.orch.spawn_replica(now)
            self._begin_cold(ep, now, wall)
        ep.orch.submit(req, now)
        if req.state is State.REJECTED:    # replica queue-full
            return False
        self._live[req.rid] = req
        self._inflight.setdefault(req.tenant, set()).add(req.rid)
        self._c_requests.inc(endpoint=ep.spec.name, tenant=req.tenant)
        self._g_state.set(STATE_CODES[self.state(ep.spec.name)],
                          endpoint=ep.spec.name)
        return True

    def _begin_cold(self, ep: _Endpoint, now: float, wall_s: float) -> None:
        ep.cold_rid = next(self._cold_rids)
        ep.cold_begin_step = self._steps
        ep.cold_wall_s = wall_s
        self._c_cold.inc(endpoint=ep.spec.name)
        self.tracer.start_trace(ep.cold_rid, now,
                                replica=f"{ep.spec.name}/ctrl",
                                kind="cold_start", endpoint=ep.spec.name)
        self.tracer.begin(ep.cold_rid, "cold_start", now,
                          replica=f"{ep.spec.name}/ctrl",
                          checkpoint_load_s=wall_s)

    def _finish_cold(self, ep: _Endpoint, now: float) -> None:
        steps = self._steps - ep.cold_begin_step
        self._g_cold_steps.set(steps, endpoint=ep.spec.name)
        self._g_cold_s.set(ep.cold_wall_s, endpoint=ep.spec.name)
        self.tracer.end(ep.cold_rid, "cold_start", now, steps=steps)
        self.tracer.finish(ep.cold_rid, now)
        ep.cold_rid = None

    # ------------------------------------------------------------ stepping
    def step(self, now: float | None = None) -> None:
        """One cluster step: every endpoint steps on the shared clock, then
        the shared transport advances exactly once (each orchestrator
        pumps its own migrations but defers the fabric to us)."""
        now = time.perf_counter() if now is None else now
        self._now = now
        for ep in self._eps.values():
            ep.orch.step(now, pump_transport=False)
        if self.transport is not None:
            self.transport.step()
        self._steps += 1
        for name, ep in self._eps.items():
            if ep.cold_rid is not None and ep.orch.warm_replicas() > 0:
                self._finish_cold(ep, now)
            self._g_state.set(STATE_CODES[self.state(name)], endpoint=name)
        # quota bookkeeping: retire finished/rejected requests
        done = [rid for rid, r in self._live.items() if r.done()]
        for rid in done:
            r = self._live.pop(rid)
            self._inflight.get(r.tenant or "default", set()).discard(rid)

    def drain_events(self) -> list:
        out: list = []
        for ep in self._eps.values():
            out.extend(ep.orch.drain_events())
        return out

    def pending(self) -> int:
        return sum(ep.orch.pending() for ep in self._eps.values())

    def finished(self, name: str | None = None) -> list[Request]:
        """Served requests — one endpoint's, or the whole cluster's."""
        eps = [self._eps[name]] if name is not None else self._eps.values()
        out: list[Request] = []
        for ep in eps:
            out.extend(ep.orch.finished)
            for e in ep.orch.engines:
                out.extend(e.finished)
        return out

    def run(self, max_steps: int = 10_000, now: float | None = None,
            dt: float = 1.0) -> list[Request]:
        """Drive the cluster until drained (wall clock, or a logical clock
        when ``now`` is given)."""
        t = now
        while self.pending() and max_steps > 0:
            self.step(t)
            if t is not None:
                t += dt
            max_steps -= 1
        return self.finished()
