"""Discrete-event cluster: layer-microservices, replicas, autoscaling.

The paper's testbed decomposes LLaMA-2-13B into 40 per-layer gRPC
microservices on a 3xA100 Kubernetes cluster.  This module reproduces that
system as an event-driven simulation whose *control plane* (profiler, HPA
autoscaler, load balancer, migration) is the same code that drives the real
JAX engine — only the data plane differs (calibrated cost model vs compiled
programs).

Key mechanism (paper §4.2): horizontal scaling of a bottleneck layer's
microservice lets the load balancer SPLIT a batch across replicas, cutting
the batch-dependent term of the layer's service time; queueing delay also
drops under concurrency.  Cold starts, heavy-tailed interference (the
source of the 230x Layer-27 hotspot) and stragglers are modelled explicitly.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Callable

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.core.loadbalancer import LoadBalancer
from repro.core.profiler import Profiler


# ----------------------------------------------------------------- cost model
@dataclasses.dataclass
class LayerCost:
    """Per-layer service time:
    t(b, n, R) = alpha + beta * ceil(b/R) + beta_tok * n + gamma*(R-1).

    ``alpha`` absorbs fixed per-call cost (kernel launch, gRPC hop, and for
    throttled hotspots the contention/thermal penalty the paper observed);
    ``beta`` is the batch-proportional compute/memory term; ``beta_tok`` is
    the prefill-token-proportional term (the engine's prefill-tokens-per-step
    telemetry is its real-backend counterpart; 0 keeps the paper-calibrated
    defaults); ``gamma`` is the scatter/gather overhead of splitting one
    batch across R replicas.
    """
    alpha: float
    beta: float
    jitter_sigma: float = 0.0       # lognormal sigma applied under load
    split_overhead: float = 0.478   # gamma
    beta_tok: float = 0.0           # per prompt-token (prefill-bound layers)

    def service_s(self, batch: int, split: int, rng: random.Random,
                  loaded: bool, tokens: int = 0) -> float:
        t = (self.alpha + self.beta * batch + self.beta_tok * tokens
             + self.split_overhead * (max(split, 1) - 1))
        if self.jitter_sigma > 0 and loaded:
            t *= rng.lognormvariate(0.0, self.jitter_sigma)
        return t


def llama2_13b_a100_costs(num_layers: int = 40, *, hotspot: int = 27,
                          seed: int = 0) -> list[LayerCost]:
    """Calibrated to the paper's testbed (LLaMA-2-13B, 3xA100-80GB, NVLink,
    input 50-2048 tokens).  Derivation of the three free constants from the
    paper's own numbers (batch 62, closed loop):

      E2E(w/o)  = others + alpha27 + 0.095*62            = 15.23 s
      E2E(with) = others + alpha27 + 0.095*ceil(62/3) + 2*gamma = 12.28 s
      => gamma = 0.478 s, and with others = 4.22 s (39 layers at their
         measured ~63 ms + two warm layers), alpha27 = 5.12 s.

    QPS then follows as batch/E2E: 4.07 -> 5.05 (paper Fig. 4b).  The Fig. 3
    '>230x Layer 27 vs Layer 30' max-latency ratio comes from the hotspot's
    heavy-tailed interference jitter under concurrency.
    """
    rng = random.Random(seed)
    costs = []
    for i in range(num_layers):
        base = 0.035 * rng.uniform(0.9, 1.1)
        beta = 0.00045 * rng.uniform(0.9, 1.1)
        costs.append(LayerCost(alpha=base, beta=beta, jitter_sigma=0.15))
    costs[hotspot] = LayerCost(alpha=5.12, beta=0.095, jitter_sigma=0.35)
    # two secondary warm spots (Fig. 3 shows several elevated layers)
    costs[15] = LayerCost(alpha=0.35, beta=0.004, jitter_sigma=0.3)
    costs[33] = LayerCost(alpha=0.8, beta=0.008, jitter_sigma=0.3)
    # fastest layer (the paper's Layer 30 reference point)
    costs[30] = LayerCost(alpha=0.028, beta=0.0003, jitter_sigma=0.05)
    return costs


# ----------------------------------------------------------------- entities
@dataclasses.dataclass
class Replica:
    svc: str
    idx: int
    ready_at: float                 # cold start completes
    busy_until: float = 0.0
    outstanding: int = 0
    failed: bool = False
    speed: float = 1.0              # <1 == straggler

    def load(self, now: float) -> float:
        return self.outstanding + max(0.0, self.busy_until - now)


class Service:
    """One microservice (a contiguous layer range) with N replicas."""

    def __init__(self, name: str, layers: tuple[int, int],
                 cost: Callable[..., float],
                 lb: LoadBalancer, autoscaler: Autoscaler | None,
                 cold_start_s: float, rng: random.Random):
        self.name = name
        self.layers = layers
        self.cost = cost
        self.lb = lb
        self.autoscaler = autoscaler
        self.cold_start_s = cold_start_s
        self.rng = rng
        self.replicas: list[Replica] = [Replica(name, 0, ready_at=0.0)]
        self.scale_events: list[tuple[float, int]] = []

    def ready(self, now: float) -> list[Replica]:
        return [r for r in self.replicas if not r.failed and r.ready_at <= now]

    def scale_to(self, now: float, n: int) -> None:
        n = max(1, n)
        cur = len([r for r in self.replicas if not r.failed])
        if n > cur:
            for i in range(n - cur):
                self.replicas.append(
                    Replica(self.name, len(self.replicas),
                            ready_at=now + self.cold_start_s))
            self.scale_events.append((now, n))
        elif n < cur:
            # retire the youngest idle replicas
            victims = [r for r in sorted(self.replicas, key=lambda r: -r.ready_at)
                       if not r.failed][: cur - n]
            for v in victims:
                self.replicas.remove(v)
            self.scale_events.append((now, n))


@dataclasses.dataclass
class SimJob:
    jid: int
    batch: int                       # queries in this batch job
    tokens: int
    t_submit: float
    stage_latency: dict[str, float] = dataclasses.field(default_factory=dict)
    t_done: float | None = None

    @property
    def e2e(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


# ----------------------------------------------------------------- cluster
@dataclasses.dataclass
class ClusterConfig:
    num_layers: int = 40
    cold_start_s: float = 12.0       # shard load: ~0.65GB layer / ~55 MB/s eff
    control_period_s: float = 5.0
    lb_policy: str = "least"
    batch_split: bool = True         # split batches across ready replicas
    seed: int = 0
    # modeled network hop between consecutive layer microservices (the
    # activations cross a service boundary; core/transport.py models the
    # same cost in steps for the serving plane).  0 keeps stages adjacent.
    hop_latency_s: float = 0.0


class SimCluster:
    """Event-driven execution of batch jobs through layer microservices."""

    def __init__(self, cfg: ClusterConfig, costs: list[LayerCost],
                 hpa: HPAConfig | None = None,
                 hpa_targets: list[int] | None = None,
                 profiler: Profiler | None = None):
        self.cfg = cfg
        self.rng = random.Random(cfg.seed)
        self.profiler = profiler or Profiler(window_s=15.0)
        self.services: list[Service] = []
        for i, c in enumerate(costs):
            scaler = None
            if hpa is not None and (hpa_targets is None or i in hpa_targets):
                scaler = Autoscaler(hpa)
            self.services.append(Service(
                f"layer/{i}", (i, i + 1), c.service_s,
                LoadBalancer(cfg.lb_policy, seed=cfg.seed + i), scaler,
                cfg.cold_start_s, self.rng))
        self._events: list[tuple[float, int, str, tuple]] = []
        self._seq = 0
        self.now = 0.0
        self.done: list[SimJob] = []
        self._inflight: dict[int, SimJob] = {}
        self.on_done: Callable[[SimJob], None] | None = None
        self._push(self.cfg.control_period_s, "control", ())

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, kind: str, payload: tuple) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, payload))

    def submit(self, job: SimJob) -> None:
        self._inflight[job.jid] = job
        self._push(job.t_submit, "stage", (job.jid, 0))

    def inject_failure(self, t: float, svc_idx: int, replica_idx: int) -> None:
        self._push(t, "fail", (svc_idx, replica_idx))

    def inject_straggler(self, t: float, svc_idx: int, replica_idx: int,
                         speed: float) -> None:
        self._push(t, "straggle", (svc_idx, replica_idx, speed))

    # ------------------------------------------------------------ mechanics
    def _run_stage(self, job: SimJob, si: int) -> None:
        svc = self.services[si]
        ready = svc.ready(self.now)
        if not ready:
            # all replicas cold/failed: retry when the first becomes ready
            t_next = min(r.ready_at for r in svc.replicas if not r.failed)
            self._push(max(t_next, self.now + 1e-6), "stage", (job.jid, si))
            return
        t_stage_start = self.now
        if self.cfg.batch_split and len(ready) > 1:
            shards = len(ready)
            per = math.ceil(job.batch / shards)
            finish = []
            for r in ready:
                loaded = r.outstanding > 0
                svc_t = svc.cost(per, shards, self.rng, loaded,
                                 tokens=job.tokens) / r.speed
                start = max(self.now, r.busy_until)
                r.busy_until = start + svc_t
                r.outstanding += 1
                finish.append(r.busy_until)
            t_done = max(finish)
            self._push(t_done, "stage_done", (job.jid, si, t_stage_start, tuple(r.idx for r in ready)))
        else:
            r = svc.lb.pick(ready, load=lambda x: x.load(self.now),
                            weight=lambda x: x.speed)
            loaded = r.outstanding > 0
            svc_t = svc.cost(job.batch, 1, self.rng, loaded,
                             tokens=job.tokens) / r.speed
            start = max(self.now, r.busy_until)
            r.busy_until = start + svc_t
            r.outstanding += 1
            self._push(r.busy_until, "stage_done", (job.jid, si, t_stage_start, (r.idx,)))

    def _stage_done(self, jid: int, si: int, t_start: float, ridxs: tuple) -> None:
        job = self._inflight[jid]
        svc = self.services[si]
        for r in svc.replicas:
            if r.idx in ridxs and r.outstanding > 0:
                r.outstanding -= 1
        lat = self.now - t_start
        job.stage_latency[svc.name] = lat
        self.profiler.observe_latency(svc.name, self.now, lat)
        self.profiler.observe_tokens(svc.name, self.now, job.tokens)
        if si + 1 < len(self.services):
            self._push(self.now + self.cfg.hop_latency_s, "stage", (jid, si + 1))
        else:
            job.t_done = self.now
            self.done.append(self._inflight.pop(jid))
            if self.on_done is not None:
                self.on_done(job)

    def _control(self) -> None:
        for svc in self.services:
            # utilization telemetry
            for r in svc.ready(self.now):
                busy = min(1.0, max(0.0, (r.busy_until - self.now)
                                    / self.cfg.control_period_s))
                self.profiler.observe_util(svc.name, self.now, busy)
            if svc.autoscaler is None:
                continue
            cfg = svc.autoscaler.cfg
            if cfg.metric == "latency":
                metric = self.profiler.p(svc.name, 95, self.now)
            elif cfg.metric == "util":
                metric = self.profiler.mean_util(svc.name, self.now)
            else:
                metric = sum(r.outstanding for r in svc.replicas)
            if metric <= 0:
                continue
            cur = len([r for r in svc.replicas if not r.failed])
            new = svc.autoscaler.evaluate(self.now, cur, metric)
            if new != cur:
                svc.scale_to(self.now, new)
        self._push(self.now + self.cfg.control_period_s, "control", ())

    # ------------------------------------------------------------ run loop
    def run(self, until: float) -> None:
        while self._events:
            t, _, kind, payload = self._events[0]
            if t > until and kind == "control" and not self._inflight:
                break
            if t > until and kind == "control":
                # keep controlling while jobs drain
                pass
            heapq.heappop(self._events)
            self.now = max(self.now, t)
            if kind == "stage":
                self._run_stage(self._inflight[payload[0]], payload[1])
            elif kind == "stage_done":
                self._stage_done(*payload)
            elif kind == "control":
                if self.now <= until or self._inflight:
                    self._control()
            elif kind == "fail":
                si, ri = payload
                for r in self.services[si].replicas:
                    if r.idx == ri:
                        r.failed = True
            elif kind == "straggle":
                si, ri, speed = payload
                for r in self.services[si].replicas:
                    if r.idx == ri:
                        r.speed = speed
            if not self._inflight and not any(
                    k in ("stage", "stage_done") for _, _, k, _ in self._events):
                if self.now >= until:
                    break

    # ------------------------------------------------------------ metrics
    def qps(self, t0: float = 0.0, t1: float | None = None) -> float:
        t1 = t1 if t1 is not None else self.now
        q = sum(j.batch for j in self.done if t0 <= (j.t_done or 0) <= t1)
        return q / max(t1 - t0, 1e-9)

    def mean_e2e(self, t0: float = 0.0) -> float:
        vals = [j.e2e for j in self.done
                if j.e2e is not None and (j.t_done or 0) >= t0]
        return sum(vals) / len(vals) if vals else 0.0

    def stage_latency_stats(self, name: str, t0: float = 0.0) -> dict:
        vals = [j.stage_latency.get(name) for j in self.done
                if (j.t_done or 0) >= t0]
        vals = [v for v in vals if v is not None]
        if not vals:
            return {"mean": 0.0, "max": 0.0, "p99": 0.0}
        vs = sorted(vals)
        return {"mean": sum(vals) / len(vals), "max": vs[-1],
                "p99": vs[min(len(vs) - 1, int(0.99 * len(vs)))]}


# ----------------------------------------------------------------- workload
def closed_loop(cluster: SimCluster, *, users: int, batch: int,
                duration_s: float, tokens=lambda rng: rng.randint(50, 2048),
                seed: int = 0) -> None:
    """Locust-style closed loop: each user resubmits on completion."""
    rng = random.Random(seed)
    jid = [0]

    def spawn(t: float) -> None:
        cluster.submit(SimJob(jid[0], batch, tokens(rng), t_submit=t))
        jid[0] += 1

    def on_done(job: SimJob) -> None:
        if job.t_done is not None and job.t_done < duration_s:
            spawn(job.t_done)

    cluster.on_done = on_done
    for _ in range(users):
        spawn(0.0)
    cluster.run(until=duration_s)
    cluster.on_done = None


def poisson_open_loop(cluster: SimCluster, *, rate_jobs_s: float, batch: int,
                      duration_s: float,
                      tokens=lambda rng: rng.randint(50, 2048),
                      seed: int = 0) -> None:
    """Open-loop Poisson arrivals (burst studies use rate step functions)."""
    rng = random.Random(seed)
    t, jid = 0.0, 0
    while t < duration_s:
        t += rng.expovariate(rate_jobs_s)
        if t >= duration_s:
            break
        cluster.submit(SimJob(jid, batch, tokens(rng), t_submit=t))
        jid += 1
    cluster.run(until=duration_s)
