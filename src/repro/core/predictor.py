"""Load prediction (paper §3 'Accurate load prediction').

Time-series forecasters driving *proactive* autoscaling: EWMA, Holt-Winters
(double-exponential: level + trend), and a windowed autoregressive model fit
by least squares.  All share observe(t, v) / forecast(horizon_s).
"""
from __future__ import annotations

import math

import numpy as np


class EWMA:
    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.level: float | None = None

    def observe(self, t: float, v: float) -> None:
        self.level = v if self.level is None else \
            self.alpha * v + (1 - self.alpha) * self.level

    def forecast(self, horizon_s: float = 0.0) -> float:
        return max(0.0, self.level or 0.0)


class HoltWinters:
    """Double exponential smoothing (level + trend); horizon-aware."""

    def __init__(self, alpha: float = 0.4, beta: float = 0.2, dt: float = 1.0):
        self.alpha, self.beta, self.dt = alpha, beta, dt
        self.level: float | None = None
        self.trend = 0.0

    def observe(self, t: float, v: float) -> None:
        if self.level is None:
            self.level = v
            return
        prev = self.level
        self.level = self.alpha * v + (1 - self.alpha) * (self.level + self.trend)
        self.trend = self.beta * (self.level - prev) + (1 - self.beta) * self.trend

    def forecast(self, horizon_s: float = 0.0) -> float:
        if self.level is None:
            return 0.0
        steps = horizon_s / self.dt
        return max(0.0, self.level + steps * self.trend)


class WindowedAR:
    """AR(p) over the last ``window`` samples, refit on demand.

    ``dt`` is the seconds between consecutive observations: it converts
    the shared ``forecast(horizon_s)`` contract into the number of
    one-step iterations the fitted model rolls forward."""

    def __init__(self, order: int = 4, window: int = 64, dt: float = 1.0):
        self.order, self.window, self.dt = order, window, dt
        self.hist: list[float] = []

    def observe(self, t: float, v: float) -> None:
        self.hist.append(v)
        if len(self.hist) > self.window:
            self.hist.pop(0)

    def _fit(self) -> np.ndarray | None:
        h = np.asarray(self.hist, np.float64)
        p = self.order
        if len(h) < p + 2:
            return None
        X = np.stack([h[i:len(h) - p + i] for i in range(p)], axis=1)
        y = h[p:]
        X = np.concatenate([X, np.ones((len(y), 1))], axis=1)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return coef

    def forecast(self, horizon_s: float = 0.0, steps: int | None = None) -> float:
        """Roll the fitted AR(p) forward ``ceil(horizon_s / dt)`` steps (at
        least one).  ``steps`` overrides the conversion for callers that
        already think in model steps."""
        if steps is None:
            steps = math.ceil(horizon_s / self.dt) if horizon_s > 0 else 1
        coef = self._fit()
        if coef is None:
            return max(0.0, self.hist[-1]) if self.hist else 0.0
        h = list(self.hist)
        for _ in range(max(1, steps)):
            x = np.asarray(h[-self.order:] + [1.0])
            # iterated AR forecasts can diverge when the fitted poles sit
            # outside the unit circle; keep every iterate finite so a long
            # horizon degrades to a clamped number, never inf/nan
            nxt = float(x @ coef)
            if not math.isfinite(nxt):
                return max(0.0, self.hist[-1])
            h.append(min(max(nxt, -1e12), 1e12))
        return max(0.0, h[-1])


def make_predictor(kind: str, **kw):
    return {"ewma": EWMA, "holt": HoltWinters, "ar": WindowedAR}[kind](**kw)
