"""Prefill/decode disaggregation (DistServe-style, paper §1 landscape).

Separate engine pools for the compute-bound prefill phase and the
memory-bound decode phase: a request is admitted to a prefill engine, runs
its prefill there, then live-migrates (the Llumnix handoff from
core/migration.py) to a decode engine.  Decode engines never run bucketed
prefills, so running decodes are never stalled behind a long prompt — the
TTFT/TPOT interference the paper's §2 calls out.

Handoff point: short (single-chunk) prompts move right after their first
token, as before.  Long chunked prompts move at the **last chunk
boundary** — the payload carries the prefill progress, the decode engine
runs the final (cheap) chunk, and the first token is sampled there, so the
KV transfer starts one chunk earlier and prefill engines emit zero decode
tokens for chunked requests.  Works on dense and paged replicas; paged
handoffs skip blocks the destination's prefix cache already holds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.cache_directory import ClusterCacheDirectory
from repro.core.loadbalancer import LoadBalancer
from repro.core.metrics import MetricsRegistry
from repro.core.migration import MigrationConfig, MigrationManager
from repro.core.tracing import Tracer
from repro.core.transport import Transport
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request, State


@dataclasses.dataclass
class DisaggConfig:
    prefill_engines: int = 1
    decode_engines: int = 1
    # decode-pool routing: "least"/"p2c"/... on kv_utilization, or
    # "directory" — handoffs route to the decode replica whose prefix cache
    # (per the cluster directory) already holds the most of the request's
    # materialised sequence, so migration ships fewer blocks
    lb_policy: str = "least"
    # "directory" load blend, in cached tokens per unit of kv_utilization:
    # the decode-pool load signal is a [0,1] fraction, so the weight must be
    # token-scale for the guard to bite — at 64, a replica 0.25 hotter needs
    # 16 more cached tokens to keep the pick (locality never pins every
    # handoff to one full replica)
    directory_load_weight: float = 64.0
    # hand chunked prompts off at their last chunk boundary instead of
    # waiting for the first token (False restores first-token-only handoff)
    chunk_handoff: bool = True
    migration: MigrationConfig = dataclasses.field(default_factory=MigrationConfig)
    # simulated cluster transport: with one configured, prefill->decode
    # handoffs stream block-granular KV chunks over the inter-pool links
    # ("n{lb_id}" nodes) instead of one synchronous payload copy — the
    # decode engine reserves the row up front and starts serving it the
    # step the last chunk lands, overlapped with both pools' compute
    transport: Transport | None = None


@dataclasses.dataclass
class DisaggStepStats:
    t: float
    handoffs_attempted: int = 0
    handoffs_succeeded: int = 0
    handoffs_failed: int = 0


class DisaggregatedServer:
    def __init__(self, make_engine: Callable[[], InferenceEngine],
                 cfg: DisaggConfig = DisaggConfig()):
        self.cfg = cfg
        self.prefill_pool = [make_engine() for _ in range(cfg.prefill_engines)]
        self.decode_pool = [make_engine() for _ in range(cfg.decode_engines)]
        # decode engines share the first prefill engine's weights (one model)
        for e in self.prefill_pool[1:] + self.decode_pool:
            e.params = self.prefill_pool[0].params
        # stable replica identities + a directory over the decode pool's
        # prefix caches: the decode-routing hook scores handoff targets by
        # cached overlap with the request's materialised sequence
        self.directory = ClusterCacheDirectory()
        # one tracer/registry across both pools: the prefill->decode handoff
        # is mid-request, so its spans must land in one trace
        self.tracer = Tracer()
        self.metrics = MetricsRegistry()
        for i, e in enumerate(self.prefill_pool + self.decode_pool):
            e.lb_id = i
            e.set_tracer(self.tracer)
            e.set_metrics(self.metrics)
        for e in self.decode_pool:
            e.attach_cache_directory(self.directory, e.lb_id)
        self.balancer = LoadBalancer(cfg.lb_policy, directory=self.directory,
                                     directory_load_weight=cfg.directory_load_weight)
        self.balancer.attach_metrics(self.metrics)
        # the disaggregated transfer is its own span family: "handoff"
        self.migrations = MigrationManager(cfg.migration,
                                           transfer_span="handoff")
        self.migrations.attach_metrics(self.metrics)
        self.finished: list[Request] = []
        self.history: list[DisaggStepStats] = []
        # pool-wide event stream: prefill-engine first tokens, handoff
        # preempts, decode-engine tokens/finishes — one per-request stream
        # across the prefill->decode migration (serving/api.py consumes it)
        self.events: list = []

    def submit(self, req: Request, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        eng = self.balancer.pick(self.prefill_pool, load=lambda e: e.pending())
        eng.submit(req, now)

    def _handoff_ready(self, pe: InferenceEngine) -> list[Request]:
        """Requests a prefill engine should hand to the decode pool now:
        everything that finished prefill (DECODE state), plus — with
        chunk_handoff — mid-prefill rows at a chunk boundary whose
        remaining prompt fits in one final chunk."""
        out = [r for r in pe.row_req.values()
               if r.state is State.DECODE and not r.done()]
        if self.cfg.chunk_handoff:
            for req in pe.migratable_requests():
                if (req.state is State.PREFILL
                        and len(req.prompt) - int(pe.pos[req.row]) <= pe.chunk):
                    out.append(req)
        return out

    def step(self, now: float | None = None) -> DisaggStepStats:
        now = time.perf_counter() if now is None else now
        a0, s0 = self.migrations.attempted, self.migrations.succeeded
        f0 = self.migrations.failed
        for pi, pe in enumerate(self.prefill_pool):
            st = pe.step(now)
            self.events.extend(st.events)
            for req in self._handoff_ready(pe):
                # KV pressure is the real decode-pool signal: occupied rows
                # under-count on paged engines, whose cost is mapped blocks.
                # Directory routing scores the sequence whose KV actually
                # moves, blended against kv_utilization through the
                # token-scale cfg.directory_load_weight
                seq = pe.migration_sequence(req.rid) \
                    if self.balancer.policy == "directory" else None
                dst = self.balancer.pick(self.decode_pool,
                                         load=lambda e: e.kv_utilization(),
                                         tokens=seq,
                                         block_size=getattr(
                                             self.decode_pool[0],
                                             "block_size", 16))
                di = len(self.prefill_pool) + self.decode_pool.index(dst)
                if self.cfg.transport is None:
                    self.migrations.migrate(pe, dst, req.rid, now,
                                            src_idx=pi, dst_idx=di)
                else:
                    # stream the handoff: the decode row activates when the
                    # last chunk lands, prefill keeps stepping meanwhile
                    self.migrations.migrate_async(
                        pe, dst, req.rid, now, self.cfg.transport,
                        f"n{pe.lb_id}", f"n{dst.lb_id}", pi, di)
            # handoff preempts were emitted on the prefill engine between
            # steps; keep them ordered before the decode pool's tokens
            self.events.extend(pe.drain_events())
        for de in self.decode_pool:
            self.events.extend(de.step(now).events)
        if self.cfg.transport is not None:
            self.migrations.pump(now, self.cfg.transport)
            self.cfg.transport.step()
        att = self.migrations.attempted - a0
        ok = self.migrations.succeeded - s0
        # async handoffs may commit steps after their attempt: count only
        # explicit refusals as failures, not transfers still in flight
        st = DisaggStepStats(t=now, handoffs_attempted=att,
                             handoffs_succeeded=ok,
                             handoffs_failed=self.migrations.failed - f0)
        self.history.append(st)
        return st

    def drain_events(self) -> list:
        """Return and clear the pool-wide event stream."""
        ev, self.events = self.events, []
        return ev

    def pending(self) -> int:
        return sum(e.pending() for e in self.prefill_pool + self.decode_pool)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.pending() and max_steps > 0:
            self.step()
            max_steps -= 1
        out = []
        for e in self.prefill_pool + self.decode_pool:
            out.extend(e.finished)
        self.finished = out
        return out
