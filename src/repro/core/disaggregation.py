"""Prefill/decode disaggregation (DistServe-style, paper §1 landscape).

Separate engine pools for the compute-bound prefill phase and the
memory-bound decode phase: a request is admitted to a prefill engine, runs
exactly its prefill + first token there, then live-migrates (the Llumnix
handoff from core/migration.py) to a decode engine.  Decode engines never
run prefills, so running decodes are never stalled behind a long prompt —
the TTFT/TPOT interference the paper's §2 calls out.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.loadbalancer import LoadBalancer
from repro.core.migration import MigrationConfig, MigrationManager
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request, State


@dataclasses.dataclass
class DisaggConfig:
    prefill_engines: int = 1
    decode_engines: int = 1
    lb_policy: str = "least"
    migration: MigrationConfig = dataclasses.field(default_factory=MigrationConfig)


class DisaggregatedServer:
    def __init__(self, make_engine: Callable[[], InferenceEngine],
                 cfg: DisaggConfig = DisaggConfig()):
        self.cfg = cfg
        self.prefill_pool = [make_engine() for _ in range(cfg.prefill_engines)]
        self.decode_pool = [make_engine() for _ in range(cfg.decode_engines)]
        # decode engines share the first prefill engine's weights (one model)
        for e in self.prefill_pool[1:] + self.decode_pool:
            e.params = self.prefill_pool[0].params
        self.balancer = LoadBalancer(cfg.lb_policy)
        self.migrations = MigrationManager(cfg.migration)
        self.finished: list[Request] = []

    def submit(self, req: Request, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        eng = self.balancer.pick(self.prefill_pool, load=lambda e: e.pending())
        eng.submit(req, now)

    def step(self, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        # prefill engines admit + produce first tokens; anything in DECODE
        # state there is immediately handed off to the decode pool
        for pi, pe in enumerate(self.prefill_pool):
            pe.step(now)
            for req in list(pe.row_req.values()):
                if req.state is not State.DECODE or req.done():
                    continue
                dst = self.balancer.pick(self.decode_pool,
                                         load=lambda e: e.pool.used)
                self.migrations.migrate(pe, dst, req.rid, now,
                                        src_idx=pi,
                                        dst_idx=len(self.prefill_pool)
                                        + self.decode_pool.index(dst))
        for de in self.decode_pool:
            de.step(now)

    def pending(self) -> int:
        return sum(e.pending() for e in self.prefill_pool + self.decode_pool)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.pending() and max_steps > 0:
            self.step()
            max_steps -= 1
        out = []
        for e in self.prefill_pool + self.decode_pool:
            out.extend(e.finished)
        self.finished = out
        return out
