"""Labeled metrics registry with Prometheus text exposition (paper §3
'Application profiling').

The paper's platform feeds Prometheus/Grafana; this module is that metrics
surface: Counter / Gauge / Histogram instruments keyed by label sets, a
:class:`MetricsRegistry` that owns them, and a text-exposition renderer in
the Prometheus format (``# HELP`` / ``# TYPE`` comment lines, then one
``name{label="value"} value`` sample per line, histograms as cumulative
``_bucket{le=...}`` series plus ``_sum``/``_count``).

:func:`parse_exposition` is the inverse — a strict line-by-line validator
used by the CI smoke test, which also checks histogram bucket monotonicity
and ``_count`` == the ``+Inf`` bucket.

Everything here is plain host-side Python (no jax, no serving imports), so
the serving layer can import it lazily without touching the core package's
import cycle, and instruments are cheap enough to update per engine step.
"""
from __future__ import annotations

import dataclasses
import math
import re

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unescape_label(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\":
            if i + 1 >= len(v):
                raise ValueError(f"dangling escape in label value {v!r}")
            nxt = v[i + 1]
            if nxt == "n":
                out.append("\n")
            elif nxt in ('"', "\\"):
                out.append(nxt)
            else:
                raise ValueError(f"bad escape \\{nxt} in label value {v!r}")
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Metric:
    """Base instrument: a family of samples keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"bad label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)

    def _key(self, labels: dict) -> tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def _label_str(self, key: tuple[str, ...],
                   extra: tuple[tuple[str, str], ...] = ()) -> str:
        pairs = list(zip(self.labelnames, key)) + list(extra)
        if not pairs:
            return ""
        body = ",".join(f'{k}="{_escape_label(v)}"' for k, v in pairs)
        return "{" + body + "}"

    def samples(self) -> list[tuple[str, str, float]]:
        """(sample name, rendered label string, value) triples."""
        raise NotImplementedError

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for name, labels, v in self.samples():
            lines.append(f"{name}{labels} {_fmt(v)}")
        return lines


class Counter(Metric):
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self._v: dict[tuple[str, ...], float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc {amount})")
        k = self._key(labels)
        self._v[k] = self._v.get(k, 0.0) + float(amount)

    def peg(self, total: float, **labels) -> None:
        """Mirror an externally-maintained cumulative total (e.g. the prefix
        cache's own ``hit_tokens`` counter) without double counting: the
        sample is raised to ``total`` and never lowered."""
        k = self._key(labels)
        self._v[k] = max(self._v.get(k, 0.0), float(total))

    def value(self, **labels) -> float:
        return self._v.get(self._key(labels), 0.0)

    def samples(self):
        return [(self.name, self._label_str(k), v)
                for k, v in sorted(self._v.items())]


class Gauge(Metric):
    """A value that goes up and down."""

    kind = "gauge"

    def __init__(self, name, help, labelnames=()):
        super().__init__(name, help, labelnames)
        self._v: dict[tuple[str, ...], float] = {}

    def set(self, value: float, **labels) -> None:
        self._v[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = self._key(labels)
        self._v[k] = self._v.get(k, 0.0) + float(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        return self._v.get(self._key(labels), 0.0)

    def samples(self):
        return [(self.name, self._label_str(k), v)
                for k, v in sorted(self._v.items())]


DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics: every bucket
    counts observations ``<= le``; ``+Inf`` is implicit and equals
    ``_count``)."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(),
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError(f"{self.name}: need at least one bucket")
        self.buckets = b
        self._counts: dict[tuple[str, ...], list[int]] = {}
        self._sum: dict[tuple[str, ...], float] = {}

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        counts = self._counts.setdefault(k, [0] * (len(self.buckets) + 1))
        # non-cumulative internally; cumulated at render time
        for i, le in enumerate(self.buckets):
            if value <= le:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sum[k] = self._sum.get(k, 0.0) + float(value)

    def count(self, **labels) -> int:
        return sum(self._counts.get(self._key(labels), []))

    def samples(self):
        out = []
        for k, counts in sorted(self._counts.items()):
            cum = 0
            for le, c in zip(self.buckets, counts):
                cum += c
                out.append((self.name + "_bucket",
                            self._label_str(k, (("le", _fmt(le)),)), cum))
            cum += counts[-1]
            out.append((self.name + "_bucket",
                        self._label_str(k, (("le", "+Inf"),)), cum))
            out.append((self.name + "_sum", self._label_str(k), self._sum[k]))
            out.append((self.name + "_count", self._label_str(k), cum))
        return out


class MetricsRegistry:
    """Owns every instrument; get-or-create accessors are idempotent so the
    engine, the scheduler hook, and the control plane can all ask for the
    same family — but a type or label-set mismatch is a hard error."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name, help, labelnames, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls:
                raise ValueError(f"{name} already registered as "
                                 f"{type(m).__name__}, not {cls.__name__}")
            if m.labelnames != tuple(labelnames):
                raise ValueError(f"{name} already registered with labels "
                                 f"{m.labelnames}, not {tuple(labelnames)}")
            return m
        m = cls(name, help, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name, help, labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name, help, labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name, help, labelnames=(),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def render(self) -> str:
        """Full Prometheus text exposition, families in name order."""
        lines = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + ("\n" if lines else "")


# --------------------------------------------------------------- validation
@dataclasses.dataclass
class Exposition:
    """Parsed exposition: sample values keyed by (name, label pairs)."""
    types: dict[str, str]
    helps: dict[str, str]
    samples: dict[tuple[str, tuple[tuple[str, str], ...]], float]

    def value(self, name: str, **labels) -> float:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        return self.samples[key]


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)(?:\s+(-?\d+))?$")


def _parse_labels(body: str, line: str) -> tuple[tuple[str, str], ...]:
    pairs, i = [], 0
    while i < len(body):
        m = re.match(r'([a-zA-Z_][a-zA-Z0-9_]*)="', body[i:])
        if not m:
            raise ValueError(f"malformed label set in line: {line!r}")
        name = m.group(1)
        i += m.end()
        j, val = i, []
        while j < len(body):
            if body[j] == "\\":
                val.append(body[j:j + 2])
                j += 2
            elif body[j] == '"':
                break
            else:
                val.append(body[j])
                j += 1
        else:
            raise ValueError(f"unterminated label value in line: {line!r}")
        pairs.append((name, _unescape_label("".join(val))))
        i = j + 1
        if i < len(body) and body[i] == ",":
            i += 1
        elif i < len(body):
            raise ValueError(f"malformed label set in line: {line!r}")
    return tuple(sorted(pairs))


def _parse_value(s: str, line: str) -> float:
    if s in ("+Inf", "Inf"):
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    try:
        return float(s)
    except ValueError:
        raise ValueError(f"bad sample value {s!r} in line: {line!r}") from None


def parse_exposition(text: str) -> Exposition:
    """Validate + parse Prometheus text exposition line by line.

    Raises ``ValueError`` on any malformed line, a duplicated sample, a
    ``# TYPE`` naming an unknown kind, histogram buckets that are not
    cumulative, or a histogram ``_count`` that disagrees with its ``+Inf``
    bucket — this is the CI smoke test's format checker.
    """
    types: dict[str, str] = {}
    helps: dict[str, str] = {}
    samples: dict[tuple[str, tuple[tuple[str, str], ...]], float] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP "):]
            name, _, help_text = rest.partition(" ")
            if not _NAME_RE.match(name):
                raise ValueError(f"bad metric name in HELP line: {line!r}")
            helps[name] = help_text
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or not _NAME_RE.match(parts[2]):
                raise ValueError(f"malformed TYPE line: {line!r}")
            if parts[3] not in ("counter", "gauge", "histogram", "summary",
                                "untyped"):
                raise ValueError(f"unknown metric type in line: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue                      # free-form comment
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"malformed sample line: {line!r}")
        name, label_body, value_s = m.group(1), m.group(2), m.group(3)
        labels = _parse_labels(label_body, line) if label_body else ()
        key = (name, labels)
        if key in samples:
            raise ValueError(f"duplicate sample: {line!r}")
        samples[key] = _parse_value(value_s, line)

    # histogram self-consistency
    for fam, kind in types.items():
        if kind != "histogram":
            continue
        series: dict[tuple[tuple[str, str], ...], list[tuple[float, float]]] = {}
        for (name, labels), v in samples.items():
            if name != fam + "_bucket":
                continue
            le = next((lv for ln, lv in labels if ln == "le"), None)
            if le is None:
                raise ValueError(f"{name}: bucket sample without le label")
            base = tuple(p for p in labels if p[0] != "le")
            series.setdefault(base, []).append(
                (_parse_value(le, le), v))
        for base, buckets in series.items():
            buckets.sort(key=lambda b: b[0])
            counts = [c for _, c in buckets]
            if any(b > a for b, a in zip(counts, counts[1:])):
                raise ValueError(
                    f"{fam}{dict(base)}: bucket counts not cumulative")
            if not buckets or not math.isinf(buckets[-1][0]):
                raise ValueError(f"{fam}{dict(base)}: missing +Inf bucket")
            cnt = samples.get((fam + "_count", base))
            if cnt is None or cnt != buckets[-1][1]:
                raise ValueError(
                    f"{fam}{dict(base)}: _count != +Inf bucket")
    return Exposition(types=types, helps=helps, samples=samples)
