"""The paper's contribution: cloud-native control plane for LLM serving.

Modules map 1:1 to the paper's six platform components (DESIGN.md §2):
loadbalancer, autoscaler, migration, predictor, profiler, microservice —
plus the cluster simulator and the real-engine orchestrator that host them.
"""
from repro.core.autoscaler import Autoscaler, HPAConfig  # noqa: F401
from repro.core.cache_directory import ClusterCacheDirectory, DirectoryStats  # noqa: F401
from repro.core.endpoints import (EndpointRegistry, ModelEndpoint,  # noqa: F401
                                  TenantQuota)
from repro.core.loadbalancer import LoadBalancer  # noqa: F401
from repro.core.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                                MetricsRegistry, parse_exposition)
from repro.core.migration import MigrationConfig, MigrationManager  # noqa: F401
from repro.core.predictor import EWMA, HoltWinters, WindowedAR, make_predictor  # noqa: F401
from repro.core.profiler import Profiler  # noqa: F401
from repro.core.tracing import (Span, Tracer,  # noqa: F401
                                attribute_slo_misses, format_attribution,
                                trace_id_hex)
