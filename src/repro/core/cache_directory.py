"""Cluster-level prefix-cache directory (ROADMAP: route-by-content).

PR 2 gave every paged replica its own block-level prefix cache; PR 3 made
migration donate transferred blocks into the destination's index.  Both
kept the *knowledge* of what is cached strictly per replica, so the load
balancer could only approximate locality by hashing the prompt's first
block ("prefix" policy).  This module lifts that knowledge to the cluster:

:class:`ClusterCacheDirectory` maps content-addressed **chain hashes**
(``serving/prefix_cache.py: chain_key`` — the radix path from the root,
folded block by block) to the set of replicas whose prefix index retains
that block.  Each replica's :class:`~repro.serving.prefix_cache.PrefixCache`
publishes insert/evict deltas through a lightweight event sink
(``attach_sink``); migration donation and scale-down drain flow through the
same two events, so adopted blocks become routable the moment the
destination indexes them.

The directory is **advisory and deliberately staleness-tolerant**: routing
on a stale entry costs at most a missed locality win, never correctness —
the replica's own cache is always the source of truth at admission.  Two
mechanisms bound the drift:

* deltas keep the directory a *conservative subset* of what replicas
  retain (an entry is only added when a block is indexed and dropped when
  one with that chain is uncached);
* periodic **reconciliation** replaces a replica's claimed set with the
  chains its radix tree can actually serve (``reachable_chains``), which
  also repairs orphaned-descendant staleness and any lost events.

Routing consumes :meth:`overlaps`: a radix-style walk of the *whole*
prompt (not just its first block) that returns, per replica, how many
leading prompt tokens that replica could serve from cache.  The
``"directory"`` load-balancer policy blends this with load slack.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.serving.prefix_cache import chain_walk


@dataclasses.dataclass
class DirectoryStats:
    """Cumulative event/consistency telemetry (control-plane visible)."""
    inserts: int = 0
    evicts: int = 0
    reconciles: int = 0
    stale_dropped: int = 0     # reconcile removed entries deltas had missed
    missed_added: int = 0      # reconcile added entries deltas had missed
    lookups: int = 0
    lookup_hit_tokens: int = 0  # best-replica overlap summed over lookups


class ClusterCacheDirectory:
    """block-chain -> replica-set index over every replica's prefix cache."""

    def __init__(self, max_intents_per_replica: int = 1024):
        self._chains: dict[int, set[int]] = {}    # chain -> replica ids
        self._replicas: dict[int, set[int]] = {}  # replica id -> chains
        # routing intents: chains a router just sent toward a replica, not
        # yet committed by that replica's index (the request is still in
        # flight).  Kept separate so the committed view stays a conservative
        # subset of replica state; merged into lookups so a burst of
        # same-prefix requests co-locates before the first one retires.
        # An intent dies when the chain commits (on_insert) or proves wrong
        # (on_evict), when its replica reconciles or departs, or — so a
        # reconcile-free configuration cannot grow without bound — when the
        # per-replica FIFO cap evicts it.
        self.max_intents_per_replica = max_intents_per_replica
        self._intent_chains: dict[int, set[int]] = {}   # chain -> replicas
        # replica -> chains in announce order (dict = insertion-ordered FIFO)
        self._intent_replicas: dict[int, dict[int, None]] = {}
        self.stats = DirectoryStats()

    # ---------------------------------------------------------- event sink
    def on_insert(self, replica: int, chain: int) -> None:
        self._chains.setdefault(chain, set()).add(replica)
        self._replicas.setdefault(replica, set()).add(chain)
        self._drop_intent(replica, chain)      # the optimism came true
        self.stats.inserts += 1

    def on_evict(self, replica: int, chain: int) -> None:
        self._discard(replica, chain)
        self._drop_intent(replica, chain)
        self.stats.evicts += 1

    def _discard(self, replica: int, chain: int) -> None:
        holders = self._chains.get(chain)
        if holders is not None:
            holders.discard(replica)
            if not holders:
                del self._chains[chain]
        claimed = self._replicas.get(replica)
        if claimed is not None:
            claimed.discard(chain)

    # -------------------------------------------------------------- intents
    def announce(self, replica: int, tokens: Sequence[int],
                 block_size: int) -> None:
        """Routing intent: ``tokens`` was just routed to ``replica``, whose
        cache will hold the prompt's full blocks once the request retires.
        Same-prefix requests arriving before then co-locate instead of
        scattering by load.  Intents are advisory-on-advisory: they never
        touch the committed view, and the next reconcile (or scale-down)
        of the replica clears them — by then the real insert events have
        either committed the chains or the optimism was wrong."""
        mine = self._intent_replicas.setdefault(replica, {})
        for chain in chain_walk(tokens, block_size):
            if chain not in self._replicas.get(replica, ()):
                self._intent_chains.setdefault(chain, set()).add(replica)
                mine[chain] = None
        while len(mine) > self.max_intents_per_replica:   # FIFO bound
            self._drop_intent(replica, next(iter(mine)))

    def _drop_intent(self, replica: int, chain: int) -> None:
        mine = self._intent_replicas.get(replica)
        if mine is not None:
            mine.pop(chain, None)
        holders = self._intent_chains.get(chain)
        if holders is not None:
            holders.discard(replica)
            if not holders:
                del self._intent_chains[chain]

    def _clear_intents(self, replica: int) -> None:
        for c in list(self._intent_replicas.get(replica, ())):
            self._drop_intent(replica, c)
        self._intent_replicas.pop(replica, None)

    # ------------------------------------------------------- reconciliation
    def reconcile(self, replica: int, chains: set[int]) -> tuple[int, int]:
        """Replace ``replica``'s claimed set with the chains its cache can
        actually serve right now.  Returns ``(dropped, added)`` — the
        entries the delta stream had missed in either direction (lost
        events, orphaned radix descendants)."""
        self._clear_intents(replica)
        claimed = self._replicas.get(replica, set())
        stale = claimed - chains
        missing = chains - claimed
        for c in stale:
            self._discard(replica, c)
        for c in missing:
            self._chains.setdefault(c, set()).add(replica)
        self._replicas[replica] = set(chains)
        self.stats.reconciles += 1
        self.stats.stale_dropped += len(stale)
        self.stats.missed_added += len(missing)
        return len(stale), len(missing)

    def drop_replica(self, replica: int) -> int:
        """Scale-down invalidation: forget everything a departing replica
        claimed (its pool is gone with it).  Returns entries removed."""
        self._clear_intents(replica)
        claimed = self._replicas.pop(replica, set())
        for c in claimed:
            holders = self._chains.get(c)
            if holders is not None:
                holders.discard(replica)
                if not holders:
                    del self._chains[c]
        return len(claimed)

    # --------------------------------------------------------------- lookup
    def overlaps(self, tokens: Sequence[int], block_size: int) -> dict[int, int]:
        """Expected cached-token overlap of ``tokens`` per replica: the
        cluster-level radix walk the ROADMAP asks for.  For each replica the
        value is the longest run of *consecutive-from-root* full blocks it
        claims, in tokens — consecutive because ``PrefixCache.match`` can
        only extend an unbroken prefix.  Capped at ``len(tokens) - 1``
        (mirroring ``PrefixCache.lookup``: the last prompt token is always
        recomputed for first-token logits)."""
        out: dict[int, int] = {}
        n = 0
        for chain in chain_walk(tokens, block_size):
            holders = self._chains.get(chain, set())
            intents = self._intent_chains.get(chain, ())
            if not holders and not intents:
                break
            extended = False
            for r in (*holders, *intents):
                if out.get(r, 0) == n:         # unbroken run from the root
                    out[r] = n + block_size
                    extended = True
            if not extended:
                break
            n += block_size
        self.stats.lookups += 1
        self.stats.lookup_hit_tokens += max(out.values(), default=0)
        return out

    def overlap(self, replica: int, tokens: Sequence[int],
                block_size: int) -> int:
        return self.overlaps(tokens, block_size).get(replica, 0)

    # ------------------------------------------------------------ telemetry
    @property
    def total_entries(self) -> int:
        """(replica, chain) claims currently held."""
        return sum(len(v) for v in self._replicas.values())

    @property
    def distinct_chains(self) -> int:
        return len(self._chains)

    def replicas(self) -> set[int]:
        return {r for r, c in self._replicas.items() if c}

    def claimed(self, replica: int) -> set[int]:
        """The chains ``replica`` currently claims (copy)."""
        return set(self._replicas.get(replica, ()))
