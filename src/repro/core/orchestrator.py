"""Orchestrator: the full cloud-native control loop over real engines.

Ties the six paper modules together for a replica set of
:class:`InferenceEngine` instances (each one a model replica, as Kubernetes
would run one pod per replica):

  profiler   <- per-step engine telemetry
  predictor  -> arrival-rate forecast
  autoscaler -> replica count (HPA law, cold start = engine build time)
  balancer   -> request routing across replicas
  migration  -> drain/rebalance live requests

The same loop drives the simulator through ``SimCluster`` (benchmarks) —
this module is the *real-engine* backend used by examples and tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.autoscaler import Autoscaler, HPAConfig
from repro.core.cache_directory import ClusterCacheDirectory
from repro.core.loadbalancer import LoadBalancer
from repro.core.metrics import MetricsRegistry
from repro.core.migration import MigrationConfig, MigrationManager
from repro.core.predictor import make_predictor
from repro.core.profiler import Profiler
from repro.core.scaling_policy import (ProactiveConfig,
                                       ProactiveScalingPolicy,
                                       ScalingSignals)
from repro.core.tracing import Tracer, attribute_slo_misses
from repro.core.transport import (DirectoryTransportClient,
                                  DirectoryTransportService, Transport)
from repro.serving.engine import InferenceEngine
from repro.serving.request import Request


@dataclasses.dataclass
class OrchestratorConfig:
    # endpoint identity: non-empty when this orchestrator is one endpoint of
    # an EndpointRegistry.  It prefixes transport node names and profiler
    # targets and becomes the {endpoint=...} metric label, so several
    # orchestrators can share one Transport and one MetricsRegistry.
    name: str = ""
    # min_replicas=0 enables scale-to-zero: the endpoint starts with no
    # engines, spins one up on first request (spawn_replica), and
    # idle_ticks_to_zero control ticks with nothing pending tear the
    # replica set back down.  The HPA never proposes 0 (K8s law floors at
    # 1), so zero-scale is orchestrator policy, not autoscaler output.
    min_replicas: int = 1
    max_replicas: int = 4
    # control ticks with pending()==0 before a min_replicas=0 endpoint
    # tears down to zero replicas.  0 disables idle teardown.
    idle_ticks_to_zero: int = 0
    hpa: HPAConfig = dataclasses.field(default_factory=lambda: HPAConfig(
        metric="queue", target=4.0, max_replicas=4, stabilization_s=5.0,
        scale_down_cooldown_s=5.0))
    migration: MigrationConfig = dataclasses.field(default_factory=MigrationConfig)
    lb_policy: str = "least"
    lb_seed: int = 0                # p2c sampling seed (bench reproducibility)
    # "directory" load blend: cached tokens one unit of pending() load is
    # worth — larger sticks harder to warm replicas, smaller spills sooner
    directory_load_weight: float = 4.0
    control_every_steps: int = 4
    predictor: str = "holt"
    cold_start_steps: int = 0       # extra steps before a new replica serves
    # proactive goodput-driven scaling: when set, desired replica counts
    # come from a ProactiveScalingPolicy (forecast arrivals at the warm-up
    # horizon over a learned capacity model, arbitrated by SLO goodput)
    # instead of the reactive HPA ratio law.  The HPA behaviors
    # (min/max clamp, stabilization, cooldowns) in cfg.hpa still apply.
    scaling: ProactiveConfig | None = None
    # cluster cache directory: full-state anti-entropy every N control ticks
    # (deltas stream continuously; reconciliation repairs lost events and
    # orphaned radix descendants).  0 disables periodic reconciliation.
    directory_reconcile_every: int = 4
    # simulated cluster transport (core/transport.py).  None keeps the
    # in-process fabric: directory deltas mutate the directory
    # synchronously and migrations move whole payloads in one call.  With
    # a Transport, directory deltas/reconciles become messages on the
    # step clock — routing sees the stale *delivered* view, and injected
    # faults exercise the conservative-subset invariant — and
    # rebalance/drain migrations stream block-granular chunks over the
    # replica links, overlapped with compute on both ends.  Node names:
    # replicas are "r{lb_id}", the control plane is "ctrl", both prefixed
    # "{name}/" when this orchestrator is a named endpoint sharing the
    # fabric with others.
    transport: Transport | None = None


class Orchestrator:
    def __init__(self, make_engine: Callable[[], InferenceEngine],
                 cfg: OrchestratorConfig = OrchestratorConfig(),
                 tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None):
        self.cfg = cfg
        self.make_engine = make_engine
        self._next_lb_id = 0
        # endpoint label ("default" for a bare orchestrator — metric labels
        # never carry empty strings) and the prefix that namespaces this
        # endpoint's transport nodes / profiler targets on shared fabric
        self._ep = cfg.name or "default"
        self._prefix = f"{cfg.name}/" if cfg.name else ""
        # cluster-wide observability: one Tracer + one MetricsRegistry that
        # every replica is rebound onto at spawn, so a migrated request's
        # spans land in one trace and the exposition covers the whole plane.
        # The registry passes shared instances; standalone use builds its own.
        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._g_replicas = self.metrics.gauge(
            "cluster_replicas", "Live replica count", ("endpoint",))
        self._g_dir_entries = self.metrics.gauge(
            "directory_entries", "Cluster cache-directory entries",
            ("endpoint",))
        self._g_dir_chains = self.metrics.gauge(
            "directory_distinct_chains", "Distinct chains in the directory",
            ("endpoint",))
        self._c_dir = self.metrics.counter(
            "directory_events_total",
            "Directory lifecycle events (inserts / evicts / reconciles / "
            "repairs)", ("kind", "endpoint"))
        # cluster-level prefix-cache directory: every paged replica's index
        # deltas stream into it; the "directory" LB policy routes on it
        self.directory = ClusterCacheDirectory()
        # optional simulated network: the directory's delta/reconcile
        # traffic and the migration KV chunks ride it when configured
        self.transport = cfg.transport
        self._dir_clients: dict[int, DirectoryTransportClient] = {}
        if self.transport is not None:
            self._dir_service = DirectoryTransportService(self.directory)
            self._dir_service.bind(self.transport, f"{self._prefix}ctrl")
            self.transport.attach_metrics(self.metrics)
        # registry hook: called before each autoscaler-driven spawn; a False
        # return vetoes it (the EndpointRegistry enforces the cluster-wide
        # replica budget and priority eviction through this)
        self.replica_gate: Callable[[], bool] | None = None
        self._idle_ticks = 0
        self.engines: list[InferenceEngine] = [self._spawn()
                                               for _ in range(cfg.min_replicas)]
        self._cold: dict[int, int] = {}
        self.profiler = Profiler(registry=self.metrics)
        # proactive goodput policy: a per-endpoint planner whose horizon
        # covers the replica warm-up lag, fed below with arrival/outcome
        # signals sampled on the control-tick clock
        self.scaling = None
        if cfg.scaling is not None:
            self.scaling = ProactiveScalingPolicy(
                cfg.scaling, cold_start_steps=cfg.cold_start_steps,
                control_every_steps=cfg.control_every_steps, name=self._ep)
            self.scaling.attach_metrics(self.metrics, endpoint=self._ep)
        self.autoscaler = Autoscaler(cfg.hpa, make_predictor(cfg.predictor),
                                     policy=self.scaling)
        self.autoscaler.attach_metrics(self.metrics, endpoint=self._ep)
        self.balancer = LoadBalancer(cfg.lb_policy, seed=cfg.lb_seed,
                                     directory=self.directory,
                                     directory_load_weight=cfg.directory_load_weight)
        self.balancer.attach_metrics(self.metrics)
        self.migrations = MigrationManager(cfg.migration)
        self.migrations.attach_metrics(self.metrics)
        self._steps = 0
        self._controls = 0
        # goodput-loop accounting: tokens served since the last control
        # tick, the tick's step stamp, and the rids already scored against
        # their SLOs (each finished request is scored exactly once)
        self._served_tokens = 0
        self._last_control_step = 0
        self._scored_rids: set[int] = set()
        self.scale_history: list[tuple[float, int]] = []
        # requests that completed on replicas since retired by scale-down
        self.finished: list[Request] = []
        # cluster-wide event stream: every replica's per-step events plus
        # migration transitions, in step order — a migrated request's tokens
        # keep flowing here from its new replica with no gap.  Consumers
        # (serving/api.py, benches) take them via drain_events().
        self.events: list = []

    def _spawn(self) -> InferenceEngine:
        """Create a replica with a stable monotonic identity: prefix-affinity
        rendezvous hashing and the cache directory key on it, so routing is
        reproducible and membership churn remaps only the departed replica's
        keys."""
        eng = self.make_engine()
        eng.lb_id = self._next_lb_id
        self._next_lb_id += 1
        # label hygiene on shared registries: two endpoints both have an
        # r0 — the endpoint prefix keeps their {replica=...} series apart
        eng.replica_label = f"{self._prefix}{eng.lb_id}"
        eng.set_tracer(self.tracer)
        eng.set_metrics(self.metrics)
        if self.transport is None:
            eng.attach_cache_directory(self.directory, eng.lb_id)
        else:
            # the replica publishes into a transport client, not the
            # directory object: its deltas become unreliable messages and
            # the control plane's view goes stale by (at least) link latency
            client = DirectoryTransportClient(self.transport,
                                              f"{self._prefix}r{eng.lb_id}",
                                              f"{self._prefix}ctrl")
            self._dir_clients[eng.lb_id] = client
            eng.attach_cache_directory(client, eng.lb_id)
        return eng

    # ------------------------------------------------------------- routing
    def submit(self, req: Request, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        # label hygiene: per-tenant metrics/quotas key on this — never let
        # an unset tenant reach the label plane as an empty string
        if req.tenant is None:
            req.tenant = "default"
        self._idle_ticks = 0
        if self.scaling is not None:
            # arrival work signal for the forecaster: what serving this
            # request will cost end to end, in tokens
            self.scaling.note_arrival(
                now, len(req.prompt) + req.sampling.max_new_tokens)
        if not self.engines:
            # scale-to-zero wakeup: first request after idle teardown spins
            # a replica up; the request queues behind its cold start below
            self.spawn_replica(now)
        live = [e for i, e in enumerate(self.engines) if self._cold.get(i, 0) <= 0]
        if not live:
            # every replica is still cold-starting: queue rather than
            # reject — the scheduler holds the request until the replica
            # warms and its first step admits it
            live = list(self.engines)
        key, tokens = None, None
        bs = getattr(live[0], "block_size", 16) if live else 16
        if self.balancer.policy == "prefix":
            # route by the prompt's first KV block so requests sharing a
            # system prefix land where its blocks are already cached
            key = tuple(req.prompt[:bs])
        elif self.balancer.policy == "directory":
            # route by the directory's cluster radix view of the *whole*
            # prompt: the replica with the deepest cached overlap wins
            # unless the load blend says it is too hot
            tokens = req.prompt
        eng = self.balancer.pick(live, load=lambda e: e.pending(),
                                 affinity_key=key, tokens=tokens,
                                 block_size=bs)
        if tokens is not None and getattr(eng, "paged", False) \
                and getattr(eng, "prefix_enabled", False):
            # routing intent: same-prefix requests arriving before this one
            # retires (and commits its blocks) co-locate with it instead of
            # scattering by load.  Gated to engines that publish into the
            # directory — an engine that never commits or reconciles must
            # not accrue phantom-overlap intents either.
            self.directory.announce(eng.lb_id, tokens, bs)
        req.replica = self.engines.index(eng)
        eng.submit(req, now)

    # ------------------------------------------------------------- control
    def _control(self, now: float) -> None:
        depth = sum(e.scheduler.depth() for e in self.engines)
        occ = sum(e.pool.used for e in self.engines)
        self.profiler.observe_util(f"{self._prefix}cluster", now,
                                   occ / max(1, sum(e.capacity for e in self.engines)))
        # KV-memory pressure: per-block on paged replicas (real bytes held),
        # per-row on dense — an autoscaler signal alongside queue depth
        cur = len(self.engines)
        kv = sum(e.kv_utilization() for e in self.engines) / max(cur, 1)
        self.profiler.observe_util(f"{self._prefix}cluster/kv", now, kv)
        metric = kv if self.cfg.hpa.metric == "kv_util" else float(depth)
        signals = None
        if self.scaling is not None:
            # snapshot for the proactive policy: queue backlog in work
            # tokens, tokens served since the last tick, warm replicas —
            # all on the logical step clock
            qtok = sum(len(r.prompt) + r.sampling.max_new_tokens
                       for e in self.engines for r in e.scheduler.queue)
            signals = ScalingSignals(
                queue_depth=depth, queue_tokens=qtok,
                served_tokens=self._served_tokens,
                steps=max(self._steps - self._last_control_step, 1),
                warm_replicas=self.warm_replicas(), total_replicas=cur)
            self._served_tokens = 0
            self._last_control_step = self._steps
            # goodput loop: score requests that finished since the last
            # tick and attribute their SLO misses (PR 6's training signal)
            fresh = [r for r in self._iter_finished()
                     if r.rid not in self._scored_rids]
            if fresh:
                self._scored_rids.update(r.rid for r in fresh)
                with_slo = [r for r in fresh
                            if r.slo_ttft is not None
                            or r.slo_tpot is not None]
                rows = attribute_slo_misses(self.tracer, with_slo) \
                    if with_slo else []
                self.scaling.observe_outcomes(fresh, rows)
        # a scaled-to-zero endpoint is invisible to the HPA: the K8s law
        # floors desired at 1, so evaluating at cur=0 would resurrect the
        # endpoint with no demand.  Wakeup happens in submit().
        new = self.autoscaler.evaluate(now, cur, metric, signals=signals) \
            if cur > 0 else 0
        if new > cur:
            spawned = 0
            for i in range(new - cur):
                if self.replica_gate is not None and not self.replica_gate():
                    break       # cluster replica budget exhausted
                self.engines.append(self._spawn())
                self._cold[len(self.engines) - 1] = self.cfg.cold_start_steps
                spawned += 1
            if spawned:
                self.scale_history.append((now, len(self.engines)))
        elif new < cur:
            # retire emptiest engines; migrate their live requests out first.
            # An engine that cannot be fully drained (targets full) survives
            # until a later tick — requests are never dropped.
            victims = sorted(range(cur), key=lambda i: self.engines[i].pool.used)
            victims = victims[: cur - new]
            keep = [i for i in range(cur) if i not in victims]
            removed = []
            for v in victims:
                self._drain(v, keep, now)
                if self.engines[v].pool.used == 0 and \
                        self.engines[v].scheduler.depth() == 0:
                    removed.append(v)
            self._remove_replicas(removed, now)

        # knative-style scale-to-zero: a min_replicas=0 endpoint with
        # nothing queued, running, or in flight for idle_ticks_to_zero
        # consecutive control ticks tears its whole replica set down (the
        # replicas are empty, so removal needs no drain)
        if self.cfg.idle_ticks_to_zero and self.cfg.min_replicas == 0 \
                and self.engines:
            if self.pending() == 0:
                self._idle_ticks += 1
                if self._idle_ticks >= self.cfg.idle_ticks_to_zero:
                    self._remove_replicas(list(range(len(self.engines))), now)
                    self._idle_ticks = 0
            else:
                self._idle_ticks = 0

        # load-imbalance migration between kept engines.  Moves sharing a
        # link split its bandwidth, so the modeled duration of each stretches
        # by the link's planned transfer count (the async path measures
        # contention instead — the transport serializes chunks fairly)
        if len(self.engines) >= 2:
            occs = [e.pool.used / e.capacity for e in self.engines]
            moves = self.migrations.plan(occs)
            link_load: dict[tuple[int, int], int] = {}
            for mv in moves:
                link_load[mv] = link_load.get(mv, 0) + 1
            for src, dst in moves:
                rid = self.migrations.pick_request(self.engines[src])
                if rid is not None:
                    self._migrate(src, dst, rid, now,
                                  concurrent=link_load[(src, dst)])

        # dst-full refusals whose backoff elapsed: re-plan each toward the
        # coolest replica holding room (capped exponential backoff —
        # a refusal re-arms the timer with a doubled delay)
        for rid in self.migrations.ready_to_retry(now):
            holder = next((i for i, e in enumerate(self.engines)
                           if any(r.rid == rid
                                  for r in e.migratable_requests())), None)
            if holder is None:
                self.migrations.clear_retry(rid)   # finished or requeued
                continue
            targets = sorted(
                (i for i in range(len(self.engines)) if i != holder),
                key=lambda i: self.engines[i].pool.used
                / self.engines[i].capacity)
            if targets:
                self._migrate(holder, targets[0], rid, now)

        # cache-directory anti-entropy + telemetry: deltas stream on every
        # index mutation; the periodic full-state reconcile repairs what
        # they can miss (orphaned radix descendants, detached sinks)
        self._controls += 1
        every = self.cfg.directory_reconcile_every
        if every and self._controls % every == 0:
            for e in self.engines:
                # over the transport the reconcile snapshot is itself a
                # message — it repairs the directory only when it survives
                # the link (and the next one repairs what this one misses)
                sink = self._dir_clients.get(e.lb_id, self.directory)
                e.reconcile_cache_directory(sink)
        # gauge, not a token counter: the util store is a plain windowed
        # float series, which is what an absolute entry count needs
        # (observe_tokens would turn it into a bogus tokens/s rate)
        self.profiler.observe_util(f"{self._prefix}cluster/directory_entries",
                                   now, float(self.directory.total_entries))
        # cluster + directory exposition (pegged: DirectoryStats keeps its
        # own cumulative counts)
        self._g_replicas.set(len(self.engines), endpoint=self._ep)
        self._g_dir_entries.set(self.directory.total_entries,
                                endpoint=self._ep)
        self._g_dir_chains.set(self.directory.distinct_chains,
                               endpoint=self._ep)
        ds = self.directory.stats
        for kind in ("inserts", "evicts", "reconciles", "stale_dropped",
                     "missed_added", "lookups"):
            self._c_dir.peg(getattr(ds, kind), kind=kind, endpoint=self._ep)

    def _iter_finished(self):
        """Every finished request the cluster currently knows: harvested
        from retired replicas plus each live engine's local list."""
        yield from self.finished
        for e in self.engines:
            yield from e.finished

    def _remove_replicas(self, removed: list[int], now: float) -> None:
        """Shared teardown bookkeeping for scale-down, priority eviction,
        and idle-to-zero: harvest finished requests and last events, detach
        and invalidate the directory, drop transport clients, and re-index
        the cold-start counters of the survivors."""
        if not removed:
            return
        gone = set(removed)
        for i in removed:          # a retired replica's served requests
            self.finished.extend(self.engines[i].finished)
            # harvest the victim's last events (drain-migration preempts)
            # before its engine object is dropped
            self.events.extend(self.engines[i].drain_events())
            # the departing replica's pool dies with it — the directory
            # must stop routing to it.  drop_replica directly (not only via
            # the sink detach): intents must die even for replicas that
            # never published (dense / prefix-disabled)
            self.engines[i].detach_cache_directory()
            self.directory.drop_replica(self.engines[i].lb_id)
            self._dir_clients.pop(self.engines[i].lb_id, None)
        keep = [i for i in range(len(self.engines)) if i not in gone]
        self._cold = {n: self._cold[o] for n, o in enumerate(keep)
                      if self._cold.get(o, 0) > 0}
        self.engines = [self.engines[i] for i in keep]
        self.scale_history.append((now, len(self.engines)))

    # --------------------------------------------------- registry surface
    def spawn_replica(self, now: float) -> float:
        """Spin up one replica outside the autoscaler loop (scale-to-zero
        wakeup, registry placement).  Returns the wall-clock seconds the
        checkpoint-load + compile path took (`make_engine`), which the
        registry reports as ``cold_start_s``; the logical-clock half of the
        cold start is ``cfg.cold_start_steps`` ticking down in step()."""
        t0 = time.perf_counter()
        self.engines.append(self._spawn())
        wall = time.perf_counter() - t0
        self._cold[len(self.engines) - 1] = self.cfg.cold_start_steps
        self.scale_history.append((now, len(self.engines)))
        return wall

    def warm_replicas(self) -> int:
        """Replicas past their cold start (schedulable right now)."""
        return sum(1 for i in range(len(self.engines))
                   if self._cold.get(i, 0) <= 0)

    def evict_coolest(self, now: float) -> bool:
        """Tear down this endpoint's coolest (emptiest) replica so a
        higher-priority endpoint can use the capacity.  Within the endpoint
        live rows drain to surviving replicas over the migration machinery;
        across endpoints this is plain teardown (models differ — KV can't
        migrate).  The last replica is only evicted when idle: a victim
        still holding work after the drain survives and the eviction
        reports failure."""
        if not self.engines:
            return False
        v = min(range(len(self.engines)),
                key=lambda i: self.engines[i].pool.used)
        keep = [i for i in range(len(self.engines)) if i != v]
        if keep:
            self._drain(v, keep, now)
        vic = self.engines[v]
        if vic.pool.used or vic.scheduler.depth():
            return False
        self._remove_replicas([v], now)
        return True

    def _migrate(self, src_i: int, dst_i: int, rid: int, now: float,
                 concurrent: int = 1) -> bool:
        """One move, on whichever fabric is configured: the synchronous
        whole-payload handoff, or a block-granular async transfer streamed
        over the replicas' transport link (the destination starts serving
        the row as soon as the last chunk lands; both replicas keep
        stepping meanwhile)."""
        src, dst = self.engines[src_i], self.engines[dst_i]
        if self.transport is None:
            ev = self.migrations.migrate(src, dst, rid, now, src_i, dst_i,
                                         concurrent=concurrent)
            return ev is not None
        return self.migrations.migrate_async(
            src, dst, rid, now, self.transport,
            f"{self._prefix}r{src.lb_id}", f"{self._prefix}r{dst.lb_id}",
            src_i, dst_i)

    def _drain(self, victim: int, keep: list[int], now: float) -> None:
        """Move every live request off a scale-down victim: decode rows and
        chunk-boundary mid-prefill rows alike (the payload carries prefill
        progress), on dense and paged replicas (block-table handoff) — paged
        scale-down drains actively instead of by attrition.  A row no target
        can admit survives here and retries next control tick."""
        src = self.engines[victim]
        for rid in [r.rid for r in src.migratable_requests()]:
            for k in keep:
                ok = self._migrate(victim, k, rid, now)
                if ok:
                    break
                if not any(r.rid == rid for r in src.migratable_requests()):
                    break  # rollback requeued it; the loop below resubmits
        # requeue anything still queued
        while src.scheduler.queue:
            req = src.scheduler.queue.popleft()
            self.submit(req, now)

    # ------------------------------------------------------------- stepping
    def step(self, now: float | None = None, *,
             pump_transport: bool = True) -> None:
        now = time.perf_counter() if now is None else now
        pre = f"{self._prefix}engine"
        for i, eng in enumerate(self.engines):
            if self._cold.get(i, 0) > 0:
                self._cold[i] -= 1
                continue
            st = eng.step(now)
            self.events.extend(st.events)
            self._served_tokens += st.tokens_out + st.prefill_tokens_true
            self.profiler.observe_latency(f"{pre}/{i}/decode", now, st.decode_s)
            self.profiler.observe_util(f"{pre}/{i}/kv", now, st.kv_util)
            if st.prefill_tokens:
                self.profiler.observe_latency(f"{pre}/{i}/prefill", now,
                                              st.prefill_s)
                self.profiler.observe_tokens(f"{pre}/{i}/prefill", now,
                                             st.prefill_tokens_true)
                self.profiler.observe_tokens(f"{pre}/{i}/prefill_padded", now,
                                             st.prefill_tokens_padded)
            if st.prefix_hit_tokens:
                self.profiler.observe_tokens(f"{pre}/{i}/prefix_hits", now,
                                             st.prefix_hit_tokens)
        self._steps += 1
        if self._steps % self.cfg.control_every_steps == 0:
            self._control(now)
            # migrations during the control tick emitted on their source
            # engines between steps; surface them in cluster step order
            for e in self.engines:
                self.events.extend(e.drain_events())
        if self.transport is not None:
            # advance the network one step with the cluster: queued KV
            # chunks (re)send under backpressure, due messages deliver —
            # directory deltas apply, finished adoptions commit their rows.
            # On a shared fabric the EndpointRegistry passes
            # pump_transport=False and steps the Transport exactly once per
            # cluster step after every endpoint has pumped its migrations.
            self.migrations.pump(now, self.transport)
            if pump_transport:
                self.transport.step()

    def drain_events(self) -> list:
        """Return and clear the cluster event stream (cross-replica, in
        step order; migration preempts included)."""
        ev, self.events = self.events, []
        return ev

    def pending(self) -> int:
        return sum(e.pending() for e in self.engines)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.pending() and max_steps > 0:
            self.step()
            max_steps -= 1
        out = list(self.finished)
        for e in self.engines:
            out.extend(e.finished)
        return out
