"""Request-lifecycle distributed tracing on the serving step clock.

One trace per request (trace id = rid), spans recorded at the engine's
request-visible transitions:

    request                 root: submit -> finish (status ok/rejected)
      queue_wait            scheduler queue residency (re-opens on requeue)
      admission             instant: row/bucket assignment, cached-prefix hits
      prefill               admission -> first token (re-opens after preempt)
        prefill_chunk[k]    instant child: one chunked-prefill slice
      decode                first token -> retire (re-opens on the dst replica)
      slo_guard_preempt     instant: SLO guard displaced this mid-prefill row
      migration_transfer    instant: KV handoff (bytes, modeled duration)
      handoff               instant: disaggregated prefill->decode transfer

``queue_wait`` / ``prefill`` / ``decode`` are the *phase* spans: they tile
the request's lifetime end to end (each opens exactly when the previous one
closes), which is what :meth:`Tracer.verify` and :meth:`Tracer.gaps` check
and what the SLO-miss attribution integrates over.  Everything else is an
instant annotation hanging off the root.

Cross-replica continuity: a migration payload carries
:meth:`Tracer.export_context` and the destination calls
:meth:`Tracer.import_context`, so span ids keep counting monotonically and
a migrated request yields ONE contiguous trace spanning both replicas —
whether the replicas share a Tracer (orchestrator) or not.

Exports: :meth:`Tracer.chrome_trace` renders Chrome/Perfetto trace-event
JSON (``ph: "X"`` complete events, microsecond timestamps, pid = replica,
tid = rid — load the file straight into https://ui.perfetto.dev), and
:func:`attribute_slo_misses` decomposes each missed ``slo_ttft``/``slo_tpot``
into queue-wait vs prefill vs decode-stall vs migration time.

Host-side Python only (no jax, no serving imports): the serving layer
imports this lazily, keeping the core<->serving import graph acyclic.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

#: span names whose closed intervals must tile a request's lifetime
PHASES = ("queue_wait", "prefill", "decode")

#: attribution bucket per span family (``prefill_chunk[k]`` -> ``prefill_chunk``)
PHASE_BUCKET = {
    "queue_wait": "queue_wait",
    "prefill": "prefill",
    "admission": "prefill",
    "prefill_chunk": "prefill",
    "migration_transfer": "migration",
    "handoff": "migration",
}


def trace_id_hex(rid: int) -> str:
    """The wire form of a trace id: the rid as a 16-hex-digit string (the
    shape OpenTelemetry trace ids take), joinable from API responses."""
    return f"{rid & (2 ** 64 - 1):016x}"


def _base(name: str) -> str:
    return name.split("[", 1)[0]


@dataclasses.dataclass
class Span:
    trace_id: int                   # == rid
    span_id: int
    name: str
    t0: float
    t1: float | None = None         # None while open
    parent_id: int | None = None
    replica: str | None = None
    status: str = "ok"
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


class _Trace:
    __slots__ = ("rid", "spans", "next_span", "root_id", "incarnation")

    def __init__(self, rid: int, next_span: int = 0,
                 root_id: int | None = None, incarnation: int = 0):
        self.rid = rid
        self.spans: list[Span] = []
        self.next_span = next_span
        self.root_id = root_id
        self.incarnation = incarnation


class Tracer:
    """Per-request span store.  Every mutator is tolerant of an unknown rid
    (returns ``None``): observability must never crash the serving path.

    Engines in one cluster share a Tracer (the orchestrator hands its own
    to every replica), so a migrated request's spans land in the same trace
    naturally; independent Tracers stay contiguous through
    export_context/import_context carried in the migration payload.
    """

    def __init__(self):
        self._live: dict[int, _Trace] = {}
        self._archive: list[_Trace] = []

    # ------------------------------------------------------------ lifecycle
    def start_trace(self, rid: int, t: float, replica: str | None = None,
                    **attrs) -> Span:
        """Open (or re-enter) the trace for ``rid``.

        A live trace whose root is still open is returned as-is — resubmits
        of a live request (scale-down drain, rollback requeue) must continue
        the same trace.  A live trace whose root has *closed* means the rid
        is being reused by a new request (benches recycle rids across
        sweeps): the finished trace is archived and a fresh incarnation
        starts."""
        tr = self._live.get(rid)
        if tr is not None:
            root = self._span(tr, tr.root_id)
            if root is not None and root.open:
                return root
            self._archive.append(tr)
            tr = _Trace(rid, incarnation=tr.incarnation + 1)
            self._live[rid] = tr
        else:
            tr = _Trace(rid)
            self._live[rid] = tr
        root = self._open(tr, "request", t, parent_id=None, replica=replica,
                          attrs=attrs)
        tr.root_id = root.span_id
        return root

    def begin(self, rid: int, name: str, t: float,
              replica: str | None = None, **attrs) -> Span | None:
        tr = self._live.get(rid)
        if tr is None:
            return None
        return self._open(tr, name, t, parent_id=tr.root_id, replica=replica,
                          attrs=attrs)

    def end(self, rid: int, name: str, t: float, status: str = "ok",
            **attrs) -> Span | None:
        """Close the most recent open span named ``name`` (no-op when none
        is open — preempt/rollback paths may race a span already closed)."""
        tr = self._live.get(rid)
        if tr is None:
            return None
        for s in reversed(tr.spans):
            if s.open and s.name == name:
                s.t1 = t
                s.status = status
                s.attrs.update(attrs)
                return s
        return None

    def annotate(self, rid: int, name: str, t: float, duration: float = 0.0,
                 replica: str | None = None, **attrs) -> Span | None:
        """Record an already-finished (instant) span."""
        tr = self._live.get(rid)
        if tr is None:
            return None
        s = self._open(tr, name, t, parent_id=tr.root_id, replica=replica,
                       attrs=attrs)
        s.t1 = t + duration
        return s

    def finish(self, rid: int, t: float, status: str = "ok") -> Span | None:
        """Close the trace: every still-open span (root included) closes at
        ``t`` with ``status`` — the retire/reject paths never orphan."""
        tr = self._live.get(rid)
        if tr is None:
            return None
        root = None
        for s in tr.spans:
            if s.open:
                s.t1 = t
                if s.span_id == tr.root_id:
                    s.status = status
                    root = s
                elif status != "ok":
                    s.status = status
        return root

    # ------------------------------------------------------------- queries
    def _span(self, tr: _Trace, span_id: int | None) -> Span | None:
        if span_id is None:
            return None
        for s in tr.spans:
            if s.span_id == span_id:
                return s
        return None

    def _open(self, tr: _Trace, name: str, t: float, parent_id: int | None,
              replica: str | None, attrs: dict) -> Span:
        s = Span(trace_id=tr.rid, span_id=tr.next_span, name=name, t0=t,
                 parent_id=parent_id, replica=replica, attrs=dict(attrs))
        tr.next_span += 1
        tr.spans.append(s)
        return s

    def spans(self, rid: int) -> list[Span]:
        """The live trace's spans for ``rid`` (empty when unknown)."""
        tr = self._live.get(rid)
        return list(tr.spans) if tr is not None else []

    def open_span(self, rid: int, name: str) -> Span | None:
        tr = self._live.get(rid)
        if tr is None:
            return None
        for s in reversed(tr.spans):
            if s.open and s.name == name:
                return s
        return None

    def count(self, rid: int, prefix: str) -> int:
        """Spans in the live trace whose base name matches ``prefix`` —
        numbers ``prefill_chunk[k]`` across replicas and preempt restarts."""
        tr = self._live.get(rid)
        if tr is None:
            return 0
        return sum(1 for s in tr.spans if _base(s.name) == prefix)

    def traces(self) -> Iterable[_Trace]:
        yield from self._archive
        yield from self._live.values()

    # ------------------------------------------------ cross-replica context
    def export_context(self, rid: int) -> dict | None:
        """Span context a migration payload carries: enough for the
        destination's Tracer to continue this trace contiguously."""
        tr = self._live.get(rid)
        if tr is None:
            return None
        return {"rid": rid, "next_span": tr.next_span,
                "root_id": tr.root_id, "incarnation": tr.incarnation}

    def import_context(self, ctx: dict | None) -> None:
        """Adopt a trace context on the destination replica.  A no-op when
        this Tracer already holds the live trace (shared-Tracer cluster);
        otherwise the trace state is recreated with the span counter offset
        so ids never collide with the source's."""
        if ctx is None:
            return
        rid = ctx["rid"]
        if rid in self._live:
            return
        self._live[rid] = _Trace(rid, next_span=ctx["next_span"],
                                 root_id=ctx.get("root_id"),
                                 incarnation=ctx.get("incarnation", 0))

    # ------------------------------------------------------------ integrity
    def verify(self, rid: int | None = None) -> list[str]:
        """Trace-integrity violations (empty list = clean): any span still
        open, or two phase spans of one trace genuinely overlapping (shared
        endpoints are the normal tiling and are fine)."""
        problems = []
        if rid is not None:
            trs: Iterable[_Trace] = ([self._live[rid]]
                                     if rid in self._live else [])
        else:
            trs = self.traces()
        for tr in trs:
            for s in tr.spans:
                if s.open:
                    problems.append(f"rid {tr.rid}: span {s.name!r} "
                                    f"(id {s.span_id}) never closed")
            phase = sorted((s for s in tr.spans
                            if s.name in PHASES and not s.open),
                           key=lambda s: (s.t0, s.t1))
            for a, b in zip(phase, phase[1:]):
                if b.t0 < a.t1 - 1e-12:
                    problems.append(
                        f"rid {tr.rid}: phase spans overlap — "
                        f"{a.name}[{a.t0},{a.t1}] vs {b.name}[{b.t0},{b.t1}]")
        return problems

    def gaps(self, rid: int, tol: float = 1e-9) -> list[tuple[float, float]]:
        """Uncovered intervals between consecutive phase spans of the live
        trace for ``rid`` — a gapless trace returns ``[]``."""
        tr = self._live.get(rid)
        if tr is None:
            return []
        phase = sorted((s for s in tr.spans
                        if s.name in PHASES and not s.open),
                       key=lambda s: (s.t0, s.t1))
        out = []
        for a, b in zip(phase, phase[1:]):
            if b.t0 - a.t1 > tol:
                out.append((a.t1, b.t0))
        return out

    # -------------------------------------------------------------- export
    def chrome_trace(self) -> dict:
        """Chrome/Perfetto trace-event JSON: one complete (``ph: "X"``)
        event per span, timestamps in microseconds, pid = replica,
        tid = rid.  Archived incarnations are included."""
        events: list[dict] = []
        pids: dict[int, str] = {}
        tids: set[tuple[int, int]] = set()
        for tr in self.traces():
            for s in tr.spans:
                try:
                    pid = int(s.replica) if s.replica is not None else 0
                except ValueError:
                    pid = abs(hash(s.replica)) % 1000
                pids.setdefault(pid, f"replica {s.replica}"
                                if s.replica is not None else "replica ?")
                tids.add((pid, tr.rid))
                t1 = s.t0 if s.t1 is None else s.t1
                args = dict(s.attrs)
                args.update(trace_id=trace_id_hex(tr.rid), span_id=s.span_id,
                            status=s.status, incarnation=tr.incarnation)
                if s.parent_id is not None:
                    args["parent_id"] = s.parent_id
                events.append({
                    "name": s.name, "cat": _base(s.name), "ph": "X",
                    "ts": s.t0 * 1e6, "dur": max(t1 - s.t0, 0.0) * 1e6,
                    "pid": pid, "tid": tr.rid, "args": args,
                })
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": label}}
                for pid, label in sorted(pids.items())]
        meta += [{"name": "thread_name", "ph": "M", "pid": pid, "tid": rid,
                  "args": {"name": f"rid {rid}"}}
                 for pid, rid in sorted(tids)]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


# ------------------------------------------------------- SLO-miss attribution
def _phase_sums(spans: list[Span], a: float, b: float) -> dict[str, float]:
    """Time each attribution bucket spent inside window [a, b]: closed-span
    durations clipped to the window, plus any modeled transfer duration
    (``duration_s``) an instant migration span carries."""
    sums = {"queue_wait": 0.0, "prefill": 0.0, "migration": 0.0}
    for s in spans:
        bucket = PHASE_BUCKET.get(_base(s.name))
        if bucket is None or s.t1 is None:
            continue
        if bucket == "prefill" and _base(s.name) != "prefill":
            continue            # admission/chunks are children of prefill
        clip = min(s.t1, b) - max(s.t0, a)
        if clip > 0:
            sums[bucket] += clip
        if bucket == "migration" and a <= s.t0 <= b:
            sums[bucket] += float(s.attrs.get("duration_s", 0.0))
    return sums


def attribute_slo_misses(tracer: Tracer, requests) -> list[dict]:
    """Decompose each missed ``slo_ttft``/``slo_tpot`` into where the time
    went: queue-wait vs prefill vs decode-stall vs migration.

    TTFT misses integrate over [arrival, first token]; TPOT misses over
    [first token, last token].  ``decode_stall`` is the residual — window
    time not accounted to the other buckets (for TPOT that is decode
    compute plus any stall behind co-batched prefill; for TTFT it is ~0).
    One row per miss: phase seconds, the dominant phase, and the trace id.
    """
    rows = []
    for r in requests:
        spans = tracer.spans(r.rid)
        if not spans:
            continue
        windows = []
        if (r.slo_ttft is not None and r.ttft is not None
                and r.ttft > r.slo_ttft):
            windows.append(("ttft", r.slo_ttft, r.ttft,
                            r.arrival, r.t_first_token))
        if (r.slo_tpot is not None and r.tpot is not None
                and r.tpot > r.slo_tpot):
            windows.append(("tpot", r.slo_tpot, r.tpot,
                            r.token_times[0], r.token_times[-1]))
        for kind, target, actual, a, b in windows:
            sums = _phase_sums(spans, a, b)
            window = max(b - a, 0.0)
            stall = max(window - sum(sums.values()), 0.0)
            parts = {**sums, "decode_stall": stall}
            rows.append({
                "rid": r.rid, "trace_id": trace_id_hex(r.rid), "slo": kind,
                "target": target, "actual": actual,
                "queue_wait": parts["queue_wait"],
                "prefill": parts["prefill"],
                "decode_stall": parts["decode_stall"],
                "migration": parts["migration"],
                "dominant": max(parts, key=lambda k: parts[k]),
            })
    rows.sort(key=lambda r: -(r["actual"] - r["target"]))
    return rows


def format_attribution(rows: list[dict]) -> str:
    """Plain-text SLO-miss attribution table."""
    if not rows:
        return "SLO-miss attribution: no misses\n"
    hdr = (f"{'rid':>6} {'slo':>5} {'target':>8} {'actual':>8} "
           f"{'queue':>8} {'prefill':>8} {'stall':>8} {'migr':>8}  dominant")
    lines = ["SLO-miss attribution:", hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['rid']:>6} {r['slo']:>5} {r['target']:>8.3f} "
            f"{r['actual']:>8.3f} {r['queue_wait']:>8.3f} "
            f"{r['prefill']:>8.3f} {r['decode_stall']:>8.3f} "
            f"{r['migration']:>8.3f}  {r['dominant']}")
    return "\n".join(lines) + "\n"
