"""Simulated cluster transport (ROADMAP "Distribute the cache directory
and the KV plane").

A message-passing fabric on the logical step clock.  Nodes are string
names ("ctrl", "r0", ...); a *link* is the directed (src, dst) pair, with
modeled latency (steps), bandwidth (bytes per step) and a bounded
in-flight queue — ``send`` returns False when the queue is full, which is
the backpressure signal senders must handle.  Concurrent messages on one
link share its bandwidth fairly, so k overlapping transfers each
serialize at B/k bytes per step and take k times longer — link contention
is modeled, not assumed away.

Messages travel in two classes.  *Reliable* messages (KV chunks, replica
teardown) are never lost or reordered — only delayed by latency,
serialization and partitions.  *Unreliable* messages (cache-directory
deltas and reconciles — gossip-grade metadata) are subject to the
injectable faults in :class:`FaultSpec`: drop (vanishes at send, the
sender cannot tell), duplicate (delivered twice), reorder (a deliverable
message is pushed behind later traffic).  Partitions stall both classes
bidirectionally until healed; nothing queued is lost.

Delivery: ``step()`` advances the clock one step, credits each queued
message its fair bandwidth share, and delivers — in FIFO order per link —
every head-of-line message whose latency has elapsed and whose bytes are
fully serialized, dispatching the handler registered for (dst, kind).

:class:`DirectoryTransportClient` / :class:`DirectoryTransportService`
put the cluster cache directory's delta-sink protocol on this fabric: the
client is a drop-in replica-side sink (same duck-typed surface
``engine.attach_cache_directory`` expects) publishing deltas as
unreliable messages; the service applies delivered messages to the real
directory, using per-replica sequence numbers so a delta or reconcile
that arrives *behind* a newer reconcile snapshot is ignored rather than
resurrecting state the snapshot already superseded.  The conservative-
subset invariant then holds on the *delivered* view whenever anti-entropy
quiesces, which is exactly the paper's staleness-tolerant metadata story:
routing runs on a stale view, reconciles repair whatever the network ate.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import deque
from typing import Any, Callable


@dataclasses.dataclass
class LinkSpec:
    """One direction of a point-to-point link."""
    latency_steps: int = 1          # steps between send and earliest delivery
    bandwidth: float = math.inf     # bytes serialized per step (shared fairly)
    max_in_flight: int = 64         # bounded queue; send() -> False when full


@dataclasses.dataclass
class FaultSpec:
    """Injectable faults for the unreliable message class."""
    drop: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class Message:
    src: str
    dst: str
    kind: str
    payload: Any
    size_bytes: int
    reliable: bool
    seq: int            # global send order (tie-break / debugging)
    sent_step: int
    credited: float = 0.0   # bytes serialized so far


class Transport:
    def __init__(self, default_link: LinkSpec | None = None,
                 faults: FaultSpec | None = None):
        self.default_link = default_link or LinkSpec()
        self.faults = faults or FaultSpec()
        self._rng = random.Random(self.faults.seed)
        self.now = 0
        self._seq = 0
        self._links: dict[tuple[str, str], LinkSpec] = {}
        self._queues: dict[tuple[str, str], deque[Message]] = {}
        self._handlers: dict[tuple[str, str], Callable[[Message, int], None]] = {}
        self._partitioned: set[tuple[str, str]] = set()
        self.counts = {"sent": 0, "delivered": 0, "dropped": 0,
                       "duplicated": 0, "reordered": 0, "rejected": 0}
        self.bytes_sent = 0
        self.bytes_delivered = 0
        self._m_msgs = None
        self._m_bytes = None
        self._g_inflight = None

    # -- topology ---------------------------------------------------------
    def set_link(self, src: str, dst: str, spec: LinkSpec,
                 symmetric: bool = False) -> None:
        self._links[(src, dst)] = spec
        if symmetric:
            self._links[(dst, src)] = spec

    def link(self, src: str, dst: str) -> LinkSpec:
        return self._links.get((src, dst), self.default_link)

    def register(self, node: str, kind: str,
                 handler: Callable[[Message, int], None]) -> None:
        """Bind the handler invoked as handler(msg, now) on delivery."""
        self._handlers[(node, kind)] = handler

    def partition(self, a: str, b: str) -> None:
        """Sever both directions between a and b (queued traffic stalls)."""
        self._partitioned.add((a, b))
        self._partitioned.add((b, a))

    def heal(self, a: str, b: str) -> None:
        self._partitioned.discard((a, b))
        self._partitioned.discard((b, a))

    def is_partitioned(self, src: str, dst: str) -> bool:
        return (src, dst) in self._partitioned

    # -- sending ----------------------------------------------------------
    def in_flight(self, src: str | None = None, dst: str | None = None) -> int:
        return sum(len(q) for (s, d), q in self._queues.items()
                   if (src is None or s == src) and (dst is None or d == dst))

    def send(self, src: str, dst: str, kind: str, payload: Any,
             size_bytes: int = 0, reliable: bool = True) -> bool:
        """Enqueue a message.  False = link queue full (backpressure): the
        caller must retry later.  True means *accepted*, not delivered —
        an unreliable message may still have been dropped in flight."""
        spec = self.link(src, dst)
        q = self._queues.setdefault((src, dst), deque())
        if len(q) >= spec.max_in_flight:
            self.counts["rejected"] += 1
            if self._m_msgs is not None:
                self._m_msgs.inc(kind=kind, outcome="rejected")
            return False
        self.counts["sent"] += 1
        self.bytes_sent += size_bytes
        if self._m_msgs is not None:
            self._m_msgs.inc(kind=kind, outcome="sent")
        if self._m_bytes is not None:
            self._m_bytes.inc(size_bytes, direction="sent")
        if not reliable and self._rng.random() < self.faults.drop:
            self.counts["dropped"] += 1
            if self._m_msgs is not None:
                self._m_msgs.inc(kind=kind, outcome="dropped")
            return True  # fire-and-forget: the sender cannot observe loss
        self._seq += 1
        msg = Message(src, dst, kind, payload, size_bytes, reliable,
                      self._seq, self.now)
        q.append(msg)
        if not reliable and self._rng.random() < self.faults.duplicate:
            self._seq += 1
            q.append(dataclasses.replace(msg, seq=self._seq))
            self.counts["duplicated"] += 1
            if self._m_msgs is not None:
                self._m_msgs.inc(kind=kind, outcome="duplicated")
        return True

    # -- clock ------------------------------------------------------------
    def _ready(self, m: Message, spec: LinkSpec) -> bool:
        return (self.now >= m.sent_step + spec.latency_steps
                and m.credited >= m.size_bytes)

    def step(self, n: int = 1) -> int:
        """Advance the transport clock n steps; returns messages delivered."""
        delivered = 0
        for _ in range(n):
            self.now += 1
            for key in list(self._queues):
                delivered += self._pump_link(key)
        if self._g_inflight is not None:
            self._g_inflight.set(self.in_flight())
        return delivered

    def _pump_link(self, key: tuple[str, str]) -> int:
        q = self._queues[key]
        if not q or self.is_partitioned(*key):
            return 0
        spec = self.link(*key)
        if math.isfinite(spec.bandwidth):
            share = spec.bandwidth / len(q)
            for m in q:
                m.credited += share
        else:
            for m in q:
                m.credited = m.size_bytes
        ready: list[Message] = []
        while q and self._ready(q[0], spec):
            ready.append(q.popleft())
        out = 0
        for i, m in enumerate(ready):
            # reorder fault: push a deliverable unreliable message behind
            # everything still queued — it overtakes nothing and is
            # overtaken by later traffic
            if (not m.reliable and len(ready) > 1
                    and self._rng.random() < self.faults.reorder):
                self.counts["reordered"] += 1
                q.append(m)
                continue
            self.counts["delivered"] += 1
            self.bytes_delivered += m.size_bytes
            if self._m_msgs is not None:
                self._m_msgs.inc(kind=m.kind, outcome="delivered")
            if self._m_bytes is not None:
                self._m_bytes.inc(m.size_bytes, direction="delivered")
            out += 1
            handler = self._handlers.get((m.dst, m.kind))
            if handler is not None:
                handler(m, self.now)
        return out

    def quiesce(self, max_steps: int = 10_000) -> int:
        """Step until every queue drains (partitions stall forever — heal
        first).  Returns steps taken."""
        steps = 0
        while self.in_flight() and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- observability ----------------------------------------------------
    def attach_metrics(self, registry) -> None:
        self._m_msgs = registry.counter(
            "transport_messages_total",
            "Transport messages by kind and outcome", ("kind", "outcome"))
        self._m_bytes = registry.counter(
            "transport_bytes_total",
            "Transport payload bytes", ("direction",))
        self._g_inflight = registry.gauge(
            "transport_in_flight", "Messages queued on all links")


class DirectoryTransportClient:
    """Replica-side cache-directory sink that publishes over the fabric.

    Duck-typed drop-in for :class:`ClusterCacheDirectory` wherever a
    replica holds a directory reference: ``on_insert``/``on_evict`` deltas
    and ``reconcile`` snapshots become *unreliable* messages (gossip-grade
    — the subset invariant tolerates loss because anti-entropy repeats),
    ``drop_replica`` is reliable (membership changes must land).  Every
    message carries a per-client monotone ``seq`` so the service can
    discard traffic that a newer reconcile snapshot already supersedes.
    """

    def __init__(self, transport: Transport, node: str,
                 directory_node: str = "ctrl", kind: str = "dir_delta"):
        self.transport = transport
        self.node = node
        self.directory_node = directory_node
        self.kind = kind
        self._seq = 0

    def _post(self, op: str, replica, reliable: bool = False,
              size_bytes: int = 64, **fields) -> None:
        self._seq += 1
        self.transport.send(
            self.node, self.directory_node, self.kind,
            {"op": op, "replica": replica, "seq": self._seq, **fields},
            size_bytes=size_bytes, reliable=reliable)

    # the PrefixCache sink surface
    def on_insert(self, replica, chain) -> None:
        self._post("insert", replica, chain=chain)

    def on_evict(self, replica, chain) -> None:
        self._post("evict", replica, chain=chain)

    # the engine attach/reconcile surface
    def reconcile(self, replica, chains) -> tuple[int, int]:
        chains = sorted(chains)
        self._post("reconcile", replica, chains=chains,
                   size_bytes=64 + 8 * len(chains))
        return (0, 0)  # applied remotely; deltas unknown at the sender

    def drop_replica(self, replica) -> int:
        self._post("drop", replica, reliable=True)
        return 0


class DirectoryTransportService:
    """Control-plane endpoint applying delivered directory messages.

    Reorder safety: a reconcile snapshot replaces the replica's claimed
    set wholesale, so any delta (or older reconcile) generated *before*
    that snapshot but delivered *after* it must be ignored — its effect is
    already inside (or superseded by) the snapshot.  The per-client
    monotone ``seq`` makes "before" checkable: track the highest applied
    reconcile seq per replica and drop anything at or below it.
    Duplicated deltas above the floor are harmless (set semantics).
    """

    def __init__(self, directory):
        self.directory = directory
        self._floor: dict[Any, int] = {}
        self.stale_ignored = 0

    def bind(self, transport: Transport, node: str,
             kind: str = "dir_delta") -> None:
        transport.register(node, kind, self.handle)

    def handle(self, msg: Message, now: int | None = None) -> None:
        p = msg.payload
        op, replica, seq = p["op"], p["replica"], p["seq"]
        if op == "drop":
            self.directory.drop_replica(replica)
            self._floor.pop(replica, None)
            return
        if op == "reconcile":
            if seq <= self._floor.get(replica, -1):
                self.stale_ignored += 1
                return
            self._floor[replica] = seq
            self.directory.reconcile(replica, set(p["chains"]))
            return
        if seq <= self._floor.get(replica, -1):
            self.stale_ignored += 1
            return
        if op == "insert":
            self.directory.on_insert(replica, p["chain"])
        elif op == "evict":
            self.directory.on_evict(replica, p["chain"])
